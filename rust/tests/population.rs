//! Population-axis locks (DESIGN.md §14, EXPERIMENTS.md E17): the
//! partial-participation sampler and the O(k) worker-state store.
//!
//! Three layers of guarantees:
//!
//! 1. **Strict generalization** — with `population == sample_k == workers`
//!    the engaged axis must be *bit-identical* to the dense engine for
//!    every algorithm, on both execution backends (the m = 16 paper-shape
//!    golden digests cannot move).
//! 2. **Sampler properties** — exactly k distinct ids per round, replay
//!    from `(sample_seed, round)` alone, round-to-round variation, and
//!    composition with the `--fault` crash/rejoin schedule (a crashed id
//!    leaves the pool; the trace and eligible-count series are recorded).
//! 3. **Store invariants** — resident state never exceeds the LRU cap,
//!    and evict → rematerialize is bit-exact: a run forced to spill
//!    *everything* every round (`sample_reserve = 0`) must produce the
//!    same digest as one that never spills at all.

use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::population::sample_cohort;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;
use olsgd::util::proptest::property;
use std::collections::BTreeSet;

/// The m = 16 paper cluster shape shared with the E13/E14 suites: 4 rounds
/// at τ = 2 with jitter stragglers so the per-worker RNG streams are live.
fn paper16(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 16;
    cfg.train_n = 16 * 64; // 64/shard -> 2 steps/epoch
    cfg.test_n = 100;
    cfg.epochs = 4.0; // 8 global steps -> 4 rounds at tau = 2
    cfg.eval_every = 2.0;
    cfg.tau = 2;
    cfg.algo = algo;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg
}

/// A small sampled shape: k = 8 machines over a population of 48, six
/// rounds so cohorts churn through the store.
fn sampled48(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 8;
    cfg.train_n = 8 * 64;
    cfg.test_n = 100;
    cfg.epochs = 6.0; // 12 global steps -> 6 rounds at tau = 2
    cfg.eval_every = 4.0;
    cfg.tau = 2;
    cfg.algo = algo;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg.set("population", "48").unwrap();
    cfg.set("sample_k", "8").unwrap();
    cfg
}

fn native_run(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

fn run_both(cfg: &ExperimentConfig) -> (TrainLog, TrainLog) {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let mut sim_cfg = cfg.clone();
    sim_cfg.execution = Execution::Sim;
    let sim = run_experiment(&rt, &sim_cfg, &train, &test).unwrap();
    let mut thr_cfg = cfg.clone();
    thr_cfg.execution = Execution::Threads;
    let thr = run_experiment(&rt, &thr_cfg, &train, &test).unwrap();
    (sim, thr)
}

// ---------------------------------------------------------------------------
// 1. Strict generalization: N == k must be the dense engine, bit for bit
// ---------------------------------------------------------------------------

/// The acceptance criterion: engaging the axis with `population == k == m`
/// keeps every pre-existing m = 16 golden digest bit-identical — for every
/// algorithm the engine dispatches (PowerSGD is a refused composition, see
/// below). With N == k the sampler selects all of `0..k` each round, ids
/// coincide with slots, and after the round-1 placement no slot ever
/// re-binds.
#[test]
fn n_equals_k_is_bit_identical_to_dense_for_every_algorithm() {
    for algo in [
        Algo::Sync,
        Algo::Local,
        Algo::Overlap,
        Algo::OverlapM,
        Algo::OverlapAda,
        Algo::OverlapGossip,
        Algo::Easgd,
        Algo::Eamsgd,
        Algo::Cocod,
    ] {
        let dense = native_run(&paper16(algo));
        let mut cfg = paper16(algo);
        cfg.set("population", "16").unwrap();
        cfg.set("sample_k", "16").unwrap();
        let pop = native_run(&cfg);
        assert_eq!(
            dense.digest(),
            pop.digest(),
            "{algo:?}: N == k engaged run drifted from the dense engine"
        );
        let c = pop.population.expect("engaged run must report population counters");
        assert_eq!(c.population, 16);
        assert_eq!(c.sample_k, 16);
        assert_eq!(c.fresh_materializations, 16, "{algo:?}: round 1 places k fresh workers");
        assert_eq!(c.store_hits, 0, "{algo:?}: a stable cohort never touches the store");
        assert_eq!(c.spill_reads, 0, "{algo:?}");
        assert_eq!(c.evictions, 0, "{algo:?}");
        assert_eq!(c.spilled_bytes, 0, "{algo:?}");
        assert_eq!(c.resident_workers_max, 16, "{algo:?}: exactly the k bound states");
        assert!(dense.population.is_none(), "dense run must not report population counters");
    }
}

/// The same identity holds on the threads backend, and sim ↔ threads stay
/// digest-equal with the axis engaged (N == k and N > k).
#[test]
fn engaged_runs_agree_across_execution_backends() {
    let mut nk = paper16(Algo::OverlapM);
    nk.set("population", "16").unwrap();
    nk.set("sample_k", "16").unwrap();
    let (sim, thr) = run_both(&nk);
    assert_eq!(sim.digest(), thr.digest(), "N == k drifted across backends");
    assert_eq!(sim.digest(), native_run(&paper16(Algo::OverlapM)).digest());

    let churn = sampled48(Algo::OverlapM);
    let (sim, thr) = run_both(&churn);
    assert_eq!(sim.digest(), thr.digest(), "N > k drifted across backends");
    assert_eq!(
        sim.population.unwrap(),
        thr.population.unwrap(),
        "store traffic must replay identically across backends"
    );
}

/// Compression composes with sampling (the error-feedback residual is part
/// of the swapped worker state): topk and qsgd run over a churning cohort
/// and stay backend-identical; N == k compressed runs match dense.
#[test]
fn compression_composes_with_sampling() {
    for kind in ["topk", "qsgd"] {
        let mut cfg = sampled48(Algo::OverlapM);
        cfg.set("compress", kind).unwrap();
        let (sim, thr) = run_both(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "compress={kind}: drift across backends");
        assert!(sim.final_loss().is_finite(), "compress={kind}");

        let mut nk = paper16(Algo::OverlapM);
        nk.set("compress", kind).unwrap();
        let dense = native_run(&nk);
        nk.set("population", "16").unwrap();
        nk.set("sample_k", "16").unwrap();
        assert_eq!(
            dense.digest(),
            native_run(&nk).digest(),
            "compress={kind}: N == k compressed run drifted from dense"
        );
    }
}

// ---------------------------------------------------------------------------
// 2. Sampler properties
// ---------------------------------------------------------------------------

/// Exactly k distinct in-range ids per round, ascending; the same
/// `(seed, round)` replays the identical cohort; cohorts vary across
/// rounds whenever more than one cohort exists.
#[test]
fn property_sampler_draws_k_distinct_replayable_round_varying_ids() {
    property("population cohort sampler", 80, |g| {
        let k = g.usize_in(1, 12);
        let n_pop = g.usize_in(k + 1, 6 * k + 64) as u64;
        let seed = g.rng().next_u64();
        let none = BTreeSet::new();
        let mut distinct_cohorts = BTreeSet::new();
        for round in 1..=24 {
            let a = sample_cohort(n_pop, k, seed, round, &none).unwrap();
            let b = sample_cohort(n_pop, k, seed, round, &none).unwrap();
            assert_eq!(a, b, "replay from (seed, round) must be exact");
            assert_eq!(a.len(), k, "cohort must have exactly k members");
            assert!(a.windows(2).all(|w| w[0] < w[1]), "ids must be distinct and ascending");
            assert!(a.iter().all(|&id| id < n_pop), "ids must be in range");
            distinct_cohorts.insert(a);
        }
        // With n_pop > k there are C(n, k) >= n > 1 possible cohorts; 24
        // independent draws landing on one single cohort would mean the
        // per-round streams are not independent.
        assert!(
            distinct_cohorts.len() > 1,
            "cohorts must vary across rounds (n = {n_pop}, k = {k})"
        );
    });
}

/// End-to-end determinism of the sampled axis: an identical config replays
/// the digest and every store counter; changing only `sample_seed` changes
/// the sampled trajectory.
#[test]
fn sampled_runs_replay_exactly_and_follow_the_sample_seed() {
    let cfg = sampled48(Algo::OverlapM);
    let a = native_run(&cfg);
    let b = native_run(&cfg);
    assert_eq!(a.digest(), b.digest(), "sampled run must replay bit-for-bit");
    assert_eq!(a.population.unwrap(), b.population.unwrap());

    let mut other = cfg.clone();
    other.set("sample_seed", "99").unwrap();
    let c = native_run(&other);
    assert_ne!(
        a.digest(),
        c.digest(),
        "a different sample_seed must select different cohorts"
    );
}

/// `--fault` composes over the sampled pool: a crashed population id
/// leaves the sampler's eligibility set until its rejoin, the events land
/// in `fault_trace`, and the eligible-count series lands in `survivors` —
/// all replayed identically across backends.
#[test]
fn faults_compose_with_sampling_over_population_ids() {
    let mut cfg = sampled48(Algo::OverlapM);
    cfg.set("fault", "crash@2:5;rejoin@5:5").unwrap();
    let (sim, thr) = run_both(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "faulted sampled run drifted across backends");
    assert_eq!(
        sim.fault_trace,
        vec![(2, "crash@2:5".to_string()), (5, "rejoin@5:5".to_string())]
    );
    assert_eq!(sim.survivors, vec![(2, 47), (5, 48)], "eligible-pool series");
    assert!(sim.final_loss().is_finite());
    // Replay purity with the fault schedule attached.
    let again = native_run(&cfg);
    assert_eq!(sim.digest(), again.digest());
}

/// The sampler itself never draws a downed id, and a rejoin restores it to
/// circulation (unit-level composition over the same code path the engine
/// uses).
#[test]
fn sampler_rejects_downed_ids() {
    let mut down = BTreeSet::new();
    down.insert(2u64);
    down.insert(11u64);
    for round in 1..=60 {
        let c = sample_cohort(16, 10, 7, round, &down).unwrap();
        assert_eq!(c.len(), 10);
        assert!(!c.contains(&2) && !c.contains(&11), "round {round} sampled a downed id");
    }
    // Draining the pool below k is a loud error, not a short cohort.
    assert!(sample_cohort(16, 15, 7, 1, &down).is_err());
}

/// Invalid compositions are refused before any state exists: sampling
/// needs a population, the population must cover the cohort, and the
/// axes that cannot preserve semantics over a per-round cohort (net
/// backend, random fault process, PowerSGD's joint basis, partitions)
/// are hard errors.
#[test]
fn invalid_population_compositions_are_refused_loudly() {
    let base = sampled48(Algo::OverlapM);

    let mut cfg = ExperimentConfig::default();
    cfg.set("sample_k", "4").unwrap();
    assert!(cfg.resolved().is_err(), "sample_k without population must be refused");

    let mut cfg = base.clone();
    cfg.set("population", "4").unwrap(); // < sample_k = 8
    assert!(cfg.resolved().is_err(), "population < k must be refused");

    let mut cfg = base.clone();
    cfg.set("fault_rate", "0.1").unwrap();
    assert!(cfg.resolved().is_err(), "the per-slot random fault process must be refused");

    let mut cfg = base.clone();
    cfg.set("fault", "partition@2:0,1|2,3").unwrap();
    assert!(cfg.resolved().is_err(), "partitions over a sampled cohort must be refused");

    let mut cfg = base.clone();
    cfg.set("fault", "crash@2:100").unwrap(); // id outside N = 48
    assert!(cfg.resolved().is_err(), "fault ids outside the population must be refused");

    let mut cfg = base.clone();
    cfg.set("compress", "powersgd").unwrap();
    assert!(cfg.resolved().is_err(), "powersgd's joint warm basis must be refused");

    let mut cfg = base;
    cfg.set("execution", "net").unwrap();
    assert!(cfg.resolved().is_err(), "the net backend must be refused");
}

// ---------------------------------------------------------------------------
// 3. Store invariants
// ---------------------------------------------------------------------------

/// The O(k) lock: however the cohorts churn, peak materialized state is
/// bounded by `sample_k + sample_reserve`, and a reserve of zero forces
/// every unbound state through the spill codec — which must not move the
/// digest relative to a reserve large enough that nothing ever spills.
/// Digest equality here proves evict → rematerialize round-trips every
/// field bit-for-bit (params, momenta, error-feedback residual, batcher
/// cursor, consumed RNG draws) through a full training run.
#[test]
fn reserve_zero_and_unbounded_reserve_are_digest_identical() {
    for algo in [Algo::OverlapM, Algo::Local, Algo::OverlapGossip] {
        let mut spill_all = sampled48(algo);
        spill_all.set("sample_reserve", "0").unwrap();
        let a = native_run(&spill_all);

        let mut never_spill = sampled48(algo);
        never_spill.set("sample_reserve", "1000").unwrap();
        let b = native_run(&never_spill);

        assert_eq!(
            a.digest(),
            b.digest(),
            "{algo:?}: the spill codec changed the trajectory"
        );

        let ca = a.population.unwrap();
        assert_eq!(ca.reserve, 0);
        assert_eq!(ca.resident_workers_max, 8, "{algo:?}: reserve 0 keeps only the k bound");
        // Reserve 0 empties the store at every boundary, so the only
        // possible store hits are same-boundary slot moves: an id staying
        // in the cohort at a different sorted position parks in phase 1
        // and is taken back in phase 2 without a spill round-trip.
        assert!(
            ca.evictions > 0 && ca.spill_reads > 0,
            "{algo:?}: 6 churning rounds over N = 48 must exercise the spill \
             (evictions = {}, reads = {})",
            ca.evictions,
            ca.spill_reads
        );
        assert!(ca.spilled_bytes > 0, "{algo:?}");

        let cb = b.population.unwrap();
        assert_eq!(cb.evictions, 0, "{algo:?}: a huge reserve must never spill");
        assert_eq!(cb.spill_reads, 0, "{algo:?}");
        assert_eq!(cb.spilled_bytes, 0, "{algo:?}");
        assert!(
            cb.resident_workers_max <= 8 + 1000,
            "{algo:?}: cap invariant ({})",
            cb.resident_workers_max
        );
        // Both runs bind the same cohorts, so total binds must agree:
        // hits + reads + fresh is invariant to the reserve.
        assert_eq!(
            ca.store_hits + ca.spill_reads + ca.fresh_materializations,
            cb.store_hits + cb.spill_reads + cb.fresh_materializations,
            "{algo:?}: bind traffic must not depend on the reserve"
        );
    }
}

/// The cap invariant across a sweep of reserves: `resident_workers_max <=
/// sample_k + sample_reserve` always, the digest never depends on the
/// reserve, and intermediate reserves blend hits with spill reads.
#[test]
fn resident_peak_respects_every_reserve_and_never_moves_the_digest() {
    let baseline = native_run(&sampled48(Algo::OverlapM));
    let base_digest = baseline.digest();
    for reserve in [0usize, 1, 4, 16, 64] {
        let mut cfg = sampled48(Algo::OverlapM);
        cfg.set("sample_reserve", &reserve.to_string()).unwrap();
        let log = native_run(&cfg);
        assert_eq!(log.digest(), base_digest, "reserve {reserve} moved the digest");
        let c = log.population.unwrap();
        assert!(
            c.resident_workers_max <= 8 + reserve as u64,
            "reserve {reserve}: peak {} exceeds k + reserve",
            c.resident_workers_max
        );
        assert_eq!(c.rounds_sampled, 6, "reserve {reserve}");
    }
}
