//! Population-axis locks (DESIGN.md §14, EXPERIMENTS.md E17): the
//! partial-participation sampler and the O(k) worker-state store.
//!
//! Four layers of guarantees:
//!
//! 1. **Strict generalization** — with `population == sample_k == workers`
//!    the engaged axis must be *bit-identical* to the dense engine for
//!    every algorithm, on both execution backends (the m = 16 paper-shape
//!    golden digests cannot move). This now includes every composition
//!    PR-8 refused: the `fault_rate`/`rejoin_rate` random process,
//!    partitions over population ids, and PowerSGD's warm bases each
//!    carry an N == k lock against their dense counterpart.
//! 2. **Sampler properties** — exactly k distinct ids per round, replay
//!    from `(sample_seed, round)` alone, round-to-round variation, and
//!    composition with the `--fault` crash/rejoin schedule (a crashed id
//!    leaves the pool; the trace and eligible-count series are recorded).
//! 3. **Store invariants** — resident state never exceeds the LRU cap,
//!    and evict → rematerialize is bit-exact: a run forced to spill
//!    *everything* every round (`sample_reserve = 0`) must produce the
//!    same digest as one that never spills at all.
//! 4. **Spill-record integrity** — truncated, bit-flipped, and
//!    wrong-version records fail with a loud error (never a silently
//!    corrupted worker), including the PowerSGD fields.

use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, Batcher, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::population::{decode_state, encode_state, sample_cohort, WorkerState};
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;
use olsgd::util::proptest::property;
use olsgd::util::rng::Rng;
use std::collections::BTreeSet;

/// The m = 16 paper cluster shape shared with the E13/E14 suites: 4 rounds
/// at τ = 2 with jitter stragglers so the per-worker RNG streams are live.
fn paper16(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 16;
    cfg.train_n = 16 * 64; // 64/shard -> 2 steps/epoch
    cfg.test_n = 100;
    cfg.epochs = 4.0; // 8 global steps -> 4 rounds at tau = 2
    cfg.eval_every = 2.0;
    cfg.tau = 2;
    cfg.algo = algo;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg
}

/// A small sampled shape: k = 8 machines over a population of 48, six
/// rounds so cohorts churn through the store.
fn sampled48(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 8;
    cfg.train_n = 8 * 64;
    cfg.test_n = 100;
    cfg.epochs = 6.0; // 12 global steps -> 6 rounds at tau = 2
    cfg.eval_every = 4.0;
    cfg.tau = 2;
    cfg.algo = algo;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg.set("population", "48").unwrap();
    cfg.set("sample_k", "8").unwrap();
    cfg
}

fn native_run(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

fn run_both(cfg: &ExperimentConfig) -> (TrainLog, TrainLog) {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let mut sim_cfg = cfg.clone();
    sim_cfg.execution = Execution::Sim;
    let sim = run_experiment(&rt, &sim_cfg, &train, &test).unwrap();
    let mut thr_cfg = cfg.clone();
    thr_cfg.execution = Execution::Threads;
    let thr = run_experiment(&rt, &thr_cfg, &train, &test).unwrap();
    (sim, thr)
}

// ---------------------------------------------------------------------------
// 1. Strict generalization: N == k must be the dense engine, bit for bit
// ---------------------------------------------------------------------------

/// The acceptance criterion: engaging the axis with `population == k == m`
/// keeps every pre-existing m = 16 golden digest bit-identical — for every
/// algorithm the engine dispatches, PowerSGD included now that its warm
/// bases travel with the worker state. With N == k the sampler selects all
/// of `0..k` each round, ids coincide with slots, and after the round-1
/// placement no slot ever re-binds.
#[test]
fn n_equals_k_is_bit_identical_to_dense_for_every_algorithm() {
    for &algo in Algo::all() {
        let dense = native_run(&paper16(algo));
        let mut cfg = paper16(algo);
        cfg.set("population", "16").unwrap();
        cfg.set("sample_k", "16").unwrap();
        let pop = native_run(&cfg);
        assert_eq!(
            dense.digest(),
            pop.digest(),
            "{algo:?}: N == k engaged run drifted from the dense engine"
        );
        let c = pop.population.expect("engaged run must report population counters");
        assert_eq!(c.population, 16);
        assert_eq!(c.sample_k, 16);
        assert_eq!(c.fresh_materializations, 16, "{algo:?}: round 1 places k fresh workers");
        assert_eq!(c.store_hits, 0, "{algo:?}: a stable cohort never touches the store");
        assert_eq!(c.spill_reads, 0, "{algo:?}");
        assert_eq!(c.evictions, 0, "{algo:?}");
        assert_eq!(c.spilled_bytes, 0, "{algo:?}");
        assert_eq!(c.resident_workers_max, 16, "{algo:?}: exactly the k bound states");
        assert!(dense.population.is_none(), "dense run must not report population counters");
    }
}

/// The same identity holds on the threads backend, and sim ↔ threads stay
/// digest-equal with the axis engaged (N == k and N > k).
#[test]
fn engaged_runs_agree_across_execution_backends() {
    let mut nk = paper16(Algo::OverlapM);
    nk.set("population", "16").unwrap();
    nk.set("sample_k", "16").unwrap();
    let (sim, thr) = run_both(&nk);
    assert_eq!(sim.digest(), thr.digest(), "N == k drifted across backends");
    assert_eq!(sim.digest(), native_run(&paper16(Algo::OverlapM)).digest());

    let churn = sampled48(Algo::OverlapM);
    let (sim, thr) = run_both(&churn);
    assert_eq!(sim.digest(), thr.digest(), "N > k drifted across backends");
    assert_eq!(
        sim.population.unwrap(),
        thr.population.unwrap(),
        "store traffic must replay identically across backends"
    );
}

/// Compression composes with sampling (the error-feedback residual — and
/// for PowerSGD the warm `Q` bases plus gradient residual — is part of the
/// swapped worker state): every codec runs over a churning cohort and
/// stays backend-identical; N == k compressed runs match dense.
#[test]
fn compression_composes_with_sampling() {
    for kind in ["topk", "qsgd", "powersgd"] {
        let mut cfg = sampled48(Algo::OverlapM);
        cfg.set("compress", kind).unwrap();
        let (sim, thr) = run_both(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "compress={kind}: drift across backends");
        assert!(sim.final_loss().is_finite(), "compress={kind}");

        let mut nk = paper16(Algo::OverlapM);
        nk.set("compress", kind).unwrap();
        let dense = native_run(&nk);
        nk.set("population", "16").unwrap();
        nk.set("sample_k", "16").unwrap();
        assert_eq!(
            dense.digest(),
            native_run(&nk).digest(),
            "compress={kind}: N == k compressed run drifted from dense"
        );
    }
}

// ---------------------------------------------------------------------------
// 2. Sampler properties
// ---------------------------------------------------------------------------

/// Exactly k distinct in-range ids per round, ascending; the same
/// `(seed, round)` replays the identical cohort; cohorts vary across
/// rounds whenever more than one cohort exists.
#[test]
fn property_sampler_draws_k_distinct_replayable_round_varying_ids() {
    property("population cohort sampler", 80, |g| {
        let k = g.usize_in(1, 12);
        let n_pop = g.usize_in(k + 1, 6 * k + 64) as u64;
        let seed = g.rng().next_u64();
        let none = BTreeSet::new();
        let mut distinct_cohorts = BTreeSet::new();
        for round in 1..=24 {
            let a = sample_cohort(n_pop, k, seed, round, &none).unwrap();
            let b = sample_cohort(n_pop, k, seed, round, &none).unwrap();
            assert_eq!(a, b, "replay from (seed, round) must be exact");
            assert_eq!(a.len(), k, "cohort must have exactly k members");
            assert!(a.windows(2).all(|w| w[0] < w[1]), "ids must be distinct and ascending");
            assert!(a.iter().all(|&id| id < n_pop), "ids must be in range");
            distinct_cohorts.insert(a);
        }
        // With n_pop > k there are C(n, k) >= n > 1 possible cohorts; 24
        // independent draws landing on one single cohort would mean the
        // per-round streams are not independent.
        assert!(
            distinct_cohorts.len() > 1,
            "cohorts must vary across rounds (n = {n_pop}, k = {k})"
        );
    });
}

/// End-to-end determinism of the sampled axis: an identical config replays
/// the digest and every store counter; changing only `sample_seed` changes
/// the sampled trajectory.
#[test]
fn sampled_runs_replay_exactly_and_follow_the_sample_seed() {
    let cfg = sampled48(Algo::OverlapM);
    let a = native_run(&cfg);
    let b = native_run(&cfg);
    assert_eq!(a.digest(), b.digest(), "sampled run must replay bit-for-bit");
    assert_eq!(a.population.unwrap(), b.population.unwrap());

    let mut other = cfg.clone();
    other.set("sample_seed", "99").unwrap();
    let c = native_run(&other);
    assert_ne!(
        a.digest(),
        c.digest(),
        "a different sample_seed must select different cohorts"
    );
}

/// `--fault` composes over the sampled pool: a crashed population id
/// leaves the sampler's eligibility set until its rejoin, the events land
/// in `fault_trace`, and the eligible-count series lands in `survivors` —
/// all replayed identically across backends.
#[test]
fn faults_compose_with_sampling_over_population_ids() {
    let mut cfg = sampled48(Algo::OverlapM);
    cfg.set("fault", "crash@2:5;rejoin@5:5").unwrap();
    let (sim, thr) = run_both(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "faulted sampled run drifted across backends");
    assert_eq!(
        sim.fault_trace,
        vec![(2, "crash@2:5".to_string()), (5, "rejoin@5:5".to_string())]
    );
    assert_eq!(sim.survivors, vec![(2, 47), (5, 48)], "eligible-pool series");
    assert!(sim.final_loss().is_finite());
    // Replay purity with the fault schedule attached.
    let again = native_run(&cfg);
    assert_eq!(sim.digest(), again.digest());
}

/// The `fault_rate`/`rejoin_rate` random process runs over population ids
/// (lazy `"fault/{id}"` streams, O(k) per round). At N == k the per-id
/// streams coincide with the dense per-worker streams, so the digest —
/// including the fault trace — must be bit-identical to the dense engine.
/// Over N > k the process replays exactly and agrees across backends.
#[test]
fn random_fault_process_composes_and_matches_dense_at_n_equals_k() {
    let mut dense = paper16(Algo::OverlapM);
    dense.set("fault_rate", "0.2").unwrap();
    dense.set("rejoin_rate", "0.5").unwrap();
    let d = native_run(&dense);
    let mut pop = dense.clone();
    pop.set("population", "16").unwrap();
    pop.set("sample_k", "16").unwrap();
    let p = native_run(&pop);
    assert_eq!(
        d.digest(),
        p.digest(),
        "per-id fault streams drifted from the dense per-worker streams at N == k"
    );
    assert_eq!(d.fault_trace, p.fault_trace);
    assert!(
        !d.fault_trace.is_empty(),
        "rate 0.2 over 16 workers x 4 rounds must fire at least once"
    );

    let mut churn = sampled48(Algo::OverlapM);
    churn.set("fault_rate", "0.1").unwrap();
    churn.set("rejoin_rate", "0.5").unwrap();
    let (sim, thr) = run_both(&churn);
    assert_eq!(sim.digest(), thr.digest(), "random-faulted sampled run drifted across backends");
    assert_eq!(sim.digest(), native_run(&churn).digest(), "replay must be exact");
    assert!(sim.final_loss().is_finite());
}

/// Partitions are declared over population-id sets (ranges allowed); the
/// cohort intersects the components, the minority parks, and `heal@`
/// restores full connectivity. A full-coverage spec at N == k is the dense
/// partition bit-for-bit; an id-range spec over N > k replays exactly and
/// agrees across backends.
#[test]
fn partitions_over_ids_compose_and_match_dense_at_n_equals_k() {
    let mut dense = paper16(Algo::OverlapM);
    dense.set("fault", "partition@2:0-7|8-15;heal@4").unwrap();
    let d = native_run(&dense);
    let mut pop = dense.clone();
    pop.set("population", "16").unwrap();
    pop.set("sample_k", "16").unwrap();
    let p = native_run(&pop);
    assert_eq!(
        d.digest(),
        p.digest(),
        "a full-coverage id partition at N == k drifted from the dense partition"
    );
    assert_eq!(d.fault_trace, p.fault_trace);
    assert_eq!(d.survivors, p.survivors, "stepping-count series under the split");

    let mut churn = sampled48(Algo::OverlapM);
    churn.set("fault", "partition@2:0-23|24-47;heal@4").unwrap();
    let (sim, thr) = run_both(&churn);
    assert_eq!(sim.digest(), thr.digest(), "partitioned sampled run drifted across backends");
    assert_eq!(sim.digest(), native_run(&churn).digest(), "replay must be exact");
    assert!(sim.final_loss().is_finite());
}

/// The sampler itself never draws a downed id, and a rejoin restores it to
/// circulation (unit-level composition over the same code path the engine
/// uses).
#[test]
fn sampler_rejects_downed_ids() {
    let mut down = BTreeSet::new();
    down.insert(2u64);
    down.insert(11u64);
    for round in 1..=60 {
        let c = sample_cohort(16, 10, 7, round, &down).unwrap();
        assert_eq!(c.len(), 10);
        assert!(!c.contains(&2) && !c.contains(&11), "round {round} sampled a downed id");
    }
    // Draining the pool below k is a loud error, not a short cohort.
    assert!(sample_cohort(16, 15, 7, 1, &down).is_err());
}

/// Only *consistency* errors are refused now: sampling needs a
/// population, the population must cover the cohort, and fault ids must
/// fall inside the registered range. Every composition PR-8 refused on
/// semantic grounds — the net backend, the random fault process,
/// PowerSGD's warm basis, partitions over ids — resolves.
#[test]
fn invalid_population_compositions_are_refused_loudly() {
    let base = sampled48(Algo::OverlapM);

    let mut cfg = ExperimentConfig::default();
    cfg.set("sample_k", "4").unwrap();
    assert!(cfg.resolved().is_err(), "sample_k without population must be refused");

    let mut cfg = base.clone();
    cfg.set("population", "4").unwrap(); // < sample_k = 8
    assert!(cfg.resolved().is_err(), "population < k must be refused");

    let mut cfg = base.clone();
    cfg.set("fault", "crash@2:100").unwrap(); // id outside N = 48
    assert!(cfg.resolved().is_err(), "fault ids outside the population must be refused");
    cfg.set("fault", "none").unwrap();
    cfg.set("fault", "partition@2:0-7|8-99").unwrap(); // 99 outside N = 48
    assert!(cfg.resolved().is_err(), "partition ids outside the population must be refused");

    // The PR-9 lifted compositions all resolve.
    let mut cfg = base.clone();
    cfg.set("fault_rate", "0.1").unwrap();
    cfg.set("rejoin_rate", "0.5").unwrap();
    assert!(cfg.resolved().is_ok(), "the per-id random fault process composes now");

    let mut cfg = base.clone();
    cfg.set("fault", "partition@2:0-23|24-47;heal@4").unwrap();
    assert!(cfg.resolved().is_ok(), "partitions over population ids compose now");

    let mut cfg = base.clone();
    cfg.set("compress", "powersgd").unwrap();
    assert!(cfg.resolved().is_ok(), "powersgd's per-worker warm bases compose now");

    let mut cfg = base;
    cfg.set("execution", "net").unwrap();
    assert!(cfg.resolved().is_ok(), "the net backend serves cohorts now");
}

// ---------------------------------------------------------------------------
// 3. Store invariants
// ---------------------------------------------------------------------------

/// The O(k) lock: however the cohorts churn, peak materialized state is
/// bounded by `sample_k + sample_reserve`, and a reserve of zero forces
/// every unbound state through the spill codec — which must not move the
/// digest relative to a reserve large enough that nothing ever spills.
/// Digest equality here proves evict → rematerialize round-trips every
/// field bit-for-bit (params, momenta, error-feedback residual, batcher
/// cursor, consumed RNG draws) through a full training run.
#[test]
fn reserve_zero_and_unbounded_reserve_are_digest_identical() {
    for algo in [Algo::OverlapM, Algo::Local, Algo::OverlapGossip] {
        let mut spill_all = sampled48(algo);
        spill_all.set("sample_reserve", "0").unwrap();
        let a = native_run(&spill_all);

        let mut never_spill = sampled48(algo);
        never_spill.set("sample_reserve", "1000").unwrap();
        let b = native_run(&never_spill);

        assert_eq!(
            a.digest(),
            b.digest(),
            "{algo:?}: the spill codec changed the trajectory"
        );

        let ca = a.population.unwrap();
        assert_eq!(ca.reserve, 0);
        assert_eq!(ca.resident_workers_max, 8, "{algo:?}: reserve 0 keeps only the k bound");
        // Reserve 0 empties the store at every boundary, so the only
        // possible store hits are same-boundary slot moves: an id staying
        // in the cohort at a different sorted position parks in phase 1
        // and is taken back in phase 2 without a spill round-trip.
        assert!(
            ca.evictions > 0 && ca.spill_reads > 0,
            "{algo:?}: 6 churning rounds over N = 48 must exercise the spill \
             (evictions = {}, reads = {})",
            ca.evictions,
            ca.spill_reads
        );
        assert!(ca.spilled_bytes > 0, "{algo:?}");

        let cb = b.population.unwrap();
        assert_eq!(cb.evictions, 0, "{algo:?}: a huge reserve must never spill");
        assert_eq!(cb.spill_reads, 0, "{algo:?}");
        assert_eq!(cb.spilled_bytes, 0, "{algo:?}");
        assert!(
            cb.resident_workers_max <= 8 + 1000,
            "{algo:?}: cap invariant ({})",
            cb.resident_workers_max
        );
        // Both runs bind the same cohorts, so total binds must agree:
        // hits + reads + fresh is invariant to the reserve.
        assert_eq!(
            ca.store_hits + ca.spill_reads + ca.fresh_materializations,
            cb.store_hits + cb.spill_reads + cb.fresh_materializations,
            "{algo:?}: bind traffic must not depend on the reserve"
        );
    }
}

/// The cap invariant across a sweep of reserves: `resident_workers_max <=
/// sample_k + sample_reserve` always, the digest never depends on the
/// reserve, and intermediate reserves blend hits with spill reads.
#[test]
fn resident_peak_respects_every_reserve_and_never_moves_the_digest() {
    let baseline = native_run(&sampled48(Algo::OverlapM));
    let base_digest = baseline.digest();
    for reserve in [0usize, 1, 4, 16, 64] {
        let mut cfg = sampled48(Algo::OverlapM);
        cfg.set("sample_reserve", &reserve.to_string()).unwrap();
        let log = native_run(&cfg);
        assert_eq!(log.digest(), base_digest, "reserve {reserve} moved the digest");
        let c = log.population.unwrap();
        assert!(
            c.resident_workers_max <= 8 + reserve as u64,
            "reserve {reserve}: peak {} exceeds k + reserve",
            c.resident_workers_max
        );
        assert_eq!(c.rounds_sampled, 6, "reserve {reserve}");
    }
}

// ---------------------------------------------------------------------------
// 4. Spill-record integrity
// ---------------------------------------------------------------------------

/// A mid-trajectory worker state exercising every optional codec branch:
/// consumed batcher and straggler draws, an error-feedback residual, and
/// (optionally) the PowerSGD gradient residual plus warm `Q` bases.
fn corrupt_probe_state(with_psgd: bool) -> WorkerState {
    let mut rng = Rng::stream(11, "straggler/3");
    for _ in 0..7 {
        rng.next_normal();
    }
    // A batcher mid-epoch (nonzero cursor, one completed epoch) so the
    // codec must carry stream positions, not just fresh construction.
    let fresh = Batcher::new((0..32u32).collect(), 11, 3, true);
    let (shard, _, brng) = fresh.spill_parts();
    let (s, spare) = brng.state();
    let batcher =
        Batcher::from_spill_parts(shard.to_vec(), 20, Rng::from_state(s, spare), 1, true);
    WorkerState {
        id: 3,
        params: (0..10).map(|i| (i as f32).sin()).collect(),
        mom: (0..10).map(|i| 0.5 - i as f32).collect(),
        mom2: Vec::new(),
        adam_t: 2.0,
        batcher,
        rng,
        residual: Some((0..10).map(|i| 1.0 / (2.0 + i as f32)).collect()),
        psgd_error: with_psgd.then(|| (0..10).map(|i| (i as f32) * 0.25).collect()),
        psgd_qs: with_psgd.then(|| {
            vec![(0..6).map(|i| (i as f32).cos()).collect(), vec![0.5f32; 4]]
        }),
    }
}

/// A spilled record that comes back differently than it went out must
/// never be resumed: truncation at *every* prefix length, a flip of *any*
/// single byte (the FNV-1a trailer catches payload flips the structural
/// checks cannot see), and an unknown version are all loud errors — with
/// and without the PowerSGD fields in the record.
#[test]
fn spill_codec_rejects_truncation_bit_flips_and_wrong_versions() {
    for with_psgd in [false, true] {
        let st = corrupt_probe_state(with_psgd);
        let mut buf = Vec::new();
        encode_state(&st, &mut buf);

        // The intact record round-trips to the identical byte string.
        let back = decode_state(&buf)
            .unwrap_or_else(|e| panic!("psgd={with_psgd}: intact record must decode: {e}"));
        let mut again = Vec::new();
        encode_state(&back, &mut again);
        assert_eq!(buf, again, "psgd={with_psgd}: decode ∘ encode must be the identity");

        // Every proper prefix is a loud truncation error.
        for cut in 0..buf.len() {
            assert!(
                decode_state(&buf[..cut]).is_err(),
                "psgd={with_psgd}: record truncated to {cut}/{} bytes must fail",
                buf.len()
            );
        }

        // Any single flipped byte fails — structurally or via the checksum.
        for pos in 0..buf.len() {
            let mut bad = buf.clone();
            bad[pos] ^= 0x20;
            assert!(
                decode_state(&bad).is_err(),
                "psgd={with_psgd}: byte {pos}/{} flipped silently decoded",
                buf.len()
            );
        }

        // Unknown versions (a stale v1 record, a future version) are
        // rejected by name before any field is read.
        for v in [1u8, 3, 99] {
            let mut bad = buf.clone();
            bad[0] = v;
            let err = decode_state(&bad).unwrap_err().to_string();
            assert!(
                err.contains("version"),
                "psgd={with_psgd}: version {v} must be rejected by the version check, got: {err}"
            );
        }

        // Trailing garbage after a valid record is refused too.
        let mut long = buf.clone();
        long.push(0);
        assert!(decode_state(&long).is_err(), "psgd={with_psgd}: trailing bytes");
    }
}
