//! Failure injection + degraded-cluster scenarios: the system must fail
//! loudly and helpfully on malformed inputs (corrupted artifacts, shape
//! mismatches, bad configs), and must *degrade gracefully* on hostile
//! clusters — slow nodes, τ larger than the run, single-worker clusters —
//! including the new scenario axes (heterogeneous τ, adaptive τ), and the
//! **E14 fault suite** (DESIGN.md §11): crash-at-round, rejoin-from-anchor,
//! and partition cases on the m = 16 paper shape with sim↔threads digest
//! equality under identical fault schedules, plus property tests showing
//! the alive-set-aware reduces and the de-biased gossip mix are exactly
//! mean-preserving over survivors.
//!
//! Artifact-free by default (native backend); the tests that exercise the
//! PJRT artifact loader are gated on the `pjrt` feature.

use olsgd::collective::ReduceScratch;
use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::fault::AliveSet;
use olsgd::metrics::TrainLog;
use olsgd::model::vecmath;
use olsgd::runtime::manifest::Manifest;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;
use olsgd::topology::Topology;
use olsgd::util::proptest::{assert_close, property};

#[cfg(feature = "pjrt")]
#[test]
fn missing_artifacts_dir_is_a_clear_error() {
    use std::path::Path;
    let msg = match olsgd::runtime::Runtime::new(Path::new("/nonexistent/artifacts")) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for missing artifacts dir"),
    };
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[cfg(feature = "pjrt")]
#[test]
fn unknown_model_is_rejected() {
    use std::path::Path;
    let runtime = olsgd::runtime::Runtime::new(Path::new("artifacts")).unwrap();
    let msg = match runtime.load_model("resnet152") {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for unknown model"),
    };
    assert!(msg.contains("not in manifest"));
}

#[test]
fn corrupted_manifest_is_rejected() {
    for bad in [
        "",                                  // empty
        "{",                                 // truncated
        r#"{"image_shape": [32, 32]}"#,      // wrong rank
        r#"{"image_shape": [32,32,3], "num_classes": 10}"#, // missing keys
        r#"{"image_shape": [32,32,3], "num_classes": 10,
            "train_batch": 32, "eval_batch": 100,
            "models": {"x": {"param_count": "ten", "tensors": [], "modules": {}}}}"#,
    ] {
        assert!(Manifest::parse(bad).is_err(), "accepted corrupt manifest: {bad:?}");
    }
}

#[test]
fn wrong_input_lengths_error_not_panic() {
    let m = ModelRuntime::native("linear").unwrap();
    let short = vec![0.0f32; m.n - 1];
    let ok_mom = vec![0.0f32; m.n];
    let images = vec![0.0f32; m.train_batch * 32 * 32 * 3];
    let labels = vec![0i32; m.train_batch];
    assert!(m.train_step(&short, &ok_mom, &images, &labels, 0.1, 0.9, 0.0).is_err());
    assert!(m.grad_step(&short, &images, &labels).is_err());
    // wrong batch
    let bad_imgs = vec![0.0f32; (m.train_batch - 1) * 32 * 32 * 3];
    let okp = vec![0.0f32; m.n];
    assert!(m.grad_step(&okp, &bad_imgs, &labels).is_err());
    // eval set not a multiple of eval batch
    let imgs = vec![0.0f32; 7 * 32 * 32 * 3];
    let lbl = vec![0i32; 7];
    assert!(m.evaluate_set(&okp, &imgs, &lbl).is_err());
}

#[test]
fn config_rejects_nonsense() {
    let mut c = ExperimentConfig::default();
    assert!(c.set("algo", "sgdx").is_err());
    assert!(c.set("tau", "-3").is_err());
    assert!(c.set("epochs", "many").is_err());
    assert!(c.set("straggler", "quantum:2").is_err());
    assert!(c.set("tau_min", "1.5").is_err());
    assert!(c.set("tau_hetero", "maybe").is_err());
    assert!(c.set("ada_patience", "-1").is_err());
    assert!(c.set("net", "infiniband").is_ok()); // stored...
    assert!(c.network().is_err()); // ...but rejected at use
    assert!(c.set("topology", "hypercube").is_ok()); // stored...
    assert!(c.topology().is_err()); // ...but rejected at use
    assert!(c.set("gossip_degree", "-2").is_err());
    assert!(c.set("hier_groups", "two").is_err());
}

fn native_run(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

#[test]
fn degenerate_single_worker_runs() {
    // m=1: all collectives are free no-ops; every algorithm must still work
    // (overlap-gossip included: its graph degenerates to the empty graph).
    for algo in [Algo::Sync, Algo::OverlapM, Algo::OverlapAda, Algo::OverlapGossip, Algo::Cocod] {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 1;
        cfg.epochs = 1.0;
        cfg.train_n = 64;
        cfg.test_n = 100;
        cfg.algo = algo;
        let log = native_run(&cfg);
        assert!(log.final_loss().is_finite(), "{algo:?} failed with m=1");
        assert_eq!(log.total_idle_s, 0.0);
    }
}

#[test]
fn tau_larger_than_total_steps_degrades_gracefully() {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 2;
    cfg.epochs = 1.0; // 2 steps per worker
    cfg.train_n = 128;
    cfg.test_n = 100;
    cfg.tau = 1000; // way beyond the run
    cfg.algo = Algo::OverlapM;
    let log = native_run(&cfg);
    assert!(log.steps > 0 && log.final_loss().is_finite());
}

#[test]
fn hetero_tau_degenerates_to_uniform_without_stragglers() {
    // No straggler -> all observed rates equal -> the hetero plan must not
    // change the schedule (identical digests).
    let mut uni = ExperimentConfig::default();
    uni.workers = 4;
    uni.epochs = 4.0;
    uni.train_n = 512;
    uni.test_n = 100;
    uni.tau = 4;
    uni.algo = Algo::Local;
    let mut het = uni.clone();
    het.tau_hetero = true;
    let a = native_run(&uni);
    let b = native_run(&het);
    assert_eq!(a.digest(), b.digest(), "hetero-τ must be a no-op on a uniform cluster");
}

/// E9 — the straggler claim, new scenario axis: a `SlowNode` cluster with
/// heterogeneous τ must show (much) less idle time than with uniform τ,
/// because the slow node runs proportionally fewer local steps per round
/// and everyone reaches the blocking boundary at ≈ the same virtual time.
#[test]
fn slow_node_with_hetero_tau_idles_less_than_uniform_tau() {
    let mut uni = ExperimentConfig::default();
    uni.workers = 4;
    uni.epochs = 8.0; // 4 steps/epoch at train_n=512/m=4/b=32 -> 8 rounds of τ=4
    uni.train_n = 512;
    uni.test_n = 100;
    uni.tau = 4;
    uni.algo = Algo::Local;
    uni.straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };
    let mut het = uni.clone();
    het.tau_hetero = true;

    let lu = native_run(&uni);
    let lh = native_run(&het);
    assert!(lu.total_idle_s > 0.0, "uniform τ must idle at the barrier");
    assert!(
        lh.total_idle_s < 0.5 * lu.total_idle_s,
        "hetero-τ did not mitigate the straggler: idle {} vs uniform {}",
        lh.total_idle_s,
        lu.total_idle_s
    );
    // Mitigation also shows up as wall-clock: the hetero run finishes sooner.
    assert!(lh.total_sim_time < lu.total_sim_time);
    assert!(lh.final_loss().is_finite());
}

// ---------------------------------------------------------------------------
// E14 — crashes, rejoins, and partitions with bit-deterministic replay
// ---------------------------------------------------------------------------

/// The m = 16 paper cluster shape, 4 rounds at τ = 2, jitter stragglers so
/// the per-worker RNG streams are live under true concurrency — the same
/// shape the hot-path locks use.
fn paper16(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 16;
    cfg.train_n = 16 * 64; // 64/shard -> 2 steps/epoch
    cfg.test_n = 100;
    cfg.epochs = 4.0; // 8 global steps -> 4 rounds at tau = 2
    cfg.eval_every = 2.0;
    cfg.tau = 2;
    cfg.algo = algo;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg
}

/// Run one config on both execution backends.
fn run_both(cfg: &ExperimentConfig) -> (TrainLog, TrainLog) {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let mut sim_cfg = cfg.clone();
    sim_cfg.execution = Execution::Sim;
    let sim = run_experiment(&rt, &sim_cfg, &train, &test).unwrap();
    let mut thr_cfg = cfg.clone();
    thr_cfg.execution = Execution::Threads;
    let thr = run_experiment(&rt, &thr_cfg, &train, &test).unwrap();
    (sim, thr)
}

/// Crash-at-round on the paper shape for the overlapped family: the fault
/// must be recorded, the survivor count must drop, both backends must agree
/// bit-for-bit, and the run must stay healthy.
#[test]
fn crash_at_round_is_backend_invariant_for_the_overlap_family() {
    for algo in [Algo::OverlapM, Algo::Cocod, Algo::OverlapGossip] {
        let mut cfg = paper16(algo);
        cfg.set("fault", "crash@3:2;crash@3:7").unwrap();
        let (sim, thr) = run_both(&cfg);
        assert_eq!(
            sim.digest(),
            thr.digest(),
            "{algo:?}: threads drifted from sim under a crash schedule"
        );
        assert_eq!(
            sim.fault_trace,
            vec![(3, "crash@3:2".to_string()), (3, "crash@3:7".to_string())],
            "{algo:?}"
        );
        assert_eq!(sim.survivors, vec![(3, 14)], "{algo:?}");
        assert!(sim.final_loss().is_finite(), "{algo:?}");
        // Deterministic replay: a second identical pair reproduces the digest.
        let (sim2, _) = run_both(&cfg);
        assert_eq!(sim.digest(), sim2.digest(), "{algo:?}: replay must be pure");
    }
}

/// Crash then rejoin: the worker comes back warm-started from the anchor,
/// the survivor series recovers, and the backends agree.
#[test]
fn rejoin_from_anchor_recovers_the_survivor_count() {
    for algo in [Algo::OverlapM, Algo::OverlapGossip, Algo::Eamsgd, Algo::Local] {
        let mut cfg = paper16(algo);
        cfg.set("fault", "crash@2:1;rejoin@4:1").unwrap();
        let (sim, thr) = run_both(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{algo:?}: rejoin schedule drifted");
        assert_eq!(sim.survivors, vec![(2, 15), (4, 16)], "{algo:?}");
        assert!(sim.final_loss().is_finite(), "{algo:?}");
        // The crash-only run is observably different from crash + rejoin.
        let mut crash_only = paper16(algo);
        crash_only.set("fault", "crash@2:1").unwrap();
        let (co, _) = run_both(&crash_only);
        assert_ne!(sim.digest(), co.digest(), "{algo:?}: rejoin must be digest-visible");
    }
}

/// Partitions: the exact-collective strategies park the minority (quorum
/// semantics) and recover it on heal; the decentralized gossip strategy
/// keeps every alive worker stepping straight through the partition.
#[test]
fn partition_parks_the_minority_for_exact_strategies_and_heals() {
    for algo in [Algo::OverlapM, Algo::Cocod] {
        let mut cfg = paper16(algo);
        cfg.set(
            "fault",
            "partition@2:0,1,2,3,4,5,6|7,8,9,10,11,12,13,14,15;heal@4",
        )
        .unwrap();
        let (sim, thr) = run_both(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{algo:?}: partition schedule drifted");
        // The 9-worker side holds the quorum; the 7-worker side parks,
        // then returns (anchor warm start) on heal.
        assert_eq!(sim.survivors, vec![(2, 9), (4, 16)], "{algo:?}");
        assert!(sim.final_loss().is_finite(), "{algo:?}");
    }
}

#[test]
fn gossip_keeps_every_component_training_through_a_partition() {
    let mut cfg = paper16(Algo::OverlapGossip);
    cfg.set(
        "fault",
        "partition@2:0,1,2,3,4,5,6|7,8,9,10,11,12,13,14,15",
    )
    .unwrap();
    let (sim, thr) = run_both(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "gossip partition drifted across backends");
    // Decentralized: the stepping count never changes — no survivor points.
    assert!(
        sim.survivors.is_empty(),
        "gossip must keep every alive worker stepping: {:?}",
        sim.survivors
    );
    assert_eq!(sim.fault_trace.len(), 1, "the partition itself is traced");
    // The partition still bites (localized mixing, fewer live edges).
    let (base, _) = run_both(&paper16(Algo::OverlapGossip));
    assert_ne!(sim.digest(), base.digest(), "the partition must be digest-visible");
    assert!(sim.final_loss().is_finite());
}

/// The acceptance-criterion regression: a schedule that never fires (and a
/// zero-rate random process) must leave the digest bit-identical to the
/// fault-free run — every fault-aware code path takes its pre-fault branch.
#[test]
fn never_firing_schedules_keep_the_fault_free_digest() {
    for algo in [Algo::OverlapM, Algo::Cocod, Algo::OverlapGossip, Algo::Local, Algo::Sync] {
        let (base, base_thr) = run_both(&paper16(algo));
        assert_eq!(base.digest(), base_thr.digest(), "{algo:?}");
        let mut cfg = paper16(algo);
        cfg.set("fault", "crash@999:1;rejoin@1000:1").unwrap();
        let (never, _) = run_both(&cfg);
        assert_eq!(
            base.digest(),
            never.digest(),
            "{algo:?}: an un-fired schedule must be bit-inert"
        );
        assert!(never.fault_trace.is_empty() && never.survivors.is_empty());
    }
}

/// The random fault process (`fault_rate` / `rejoin_rate`) is a seeded
/// coordinator-side draw: reproducible run to run and identical across
/// backends.
#[test]
fn random_fault_process_is_deterministic_and_backend_invariant() {
    let mut cfg = paper16(Algo::OverlapM);
    cfg.epochs = 8.0; // 8 rounds: enough draws that the process fires
    cfg.set("fault_rate", "0.3").unwrap();
    cfg.set("rejoin_rate", "0.5").unwrap();
    let (sim, thr) = run_both(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "random faults drifted across backends");
    assert!(
        !sim.fault_trace.is_empty(),
        "a 30% per-worker rate over 8 rounds must fire"
    );
    let (sim2, _) = run_both(&cfg);
    assert_eq!(sim.digest(), sim2.digest(), "random faults must replay identically");
    assert_eq!(sim.fault_trace, sim2.fault_trace);
    assert!(sim.final_loss().is_finite());
}

/// Impossible or unsupported schedules fail loudly, not silently.
#[test]
fn impossible_fault_schedules_fail_loudly() {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let attempt = |cfg: &ExperimentConfig| {
        let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
        let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
        run_experiment(&rt, cfg, &train, &test)
    };
    // Killing every worker.
    let mut cfg = paper16(Algo::OverlapM);
    cfg.workers = 2;
    cfg.train_n = 128;
    cfg.set("fault", "crash@2:0;crash@2:1").unwrap();
    let msg = format!("{:#}", attempt(&cfg).unwrap_err());
    assert!(msg.contains("no live worker"), "unhelpful error: {msg}");
    // Out-of-range worker.
    let mut cfg = paper16(Algo::OverlapM);
    cfg.set("fault", "crash@2:99").unwrap();
    let msg = format!("{:#}", attempt(&cfg).unwrap_err());
    assert!(msg.contains("99"), "unhelpful error: {msg}");
    // `--algo powersgd` *is* sync under `--compress powersgd`, so pairing it
    // with a different compressor is contradictory and fails loudly. (The old
    // "powersgd cannot run under fault injection" refusal is gone: per-worker
    // error-feedback state crashes and rejoins cleanly — see
    // tests/compression.rs::powersgd_survives_crash_and_rejoin.)
    let mut cfg = paper16(Algo::PowerSgd);
    cfg.set("compress", "topk").unwrap();
    let msg = format!("{:#}", attempt(&cfg).unwrap_err());
    assert!(msg.contains("powersgd"), "unhelpful error: {msg}");
}

// ---------------------------------------------------------------------------
// Property tests — survivor-mean preservation of the masked data planes
// ---------------------------------------------------------------------------

/// Alive-set-aware ring/tree/hier reduces are exactly mean-preserving over
/// the survivors, for random alive subsets (including n < m chunking shapes
/// and the 1-survivor edge), and leave dead buffers bit-untouched.
#[test]
fn property_masked_exact_reduces_are_mean_preserving_over_survivors() {
    property("alive-set reduce == survivor mean", 120, |g| {
        let m = g.usize_in(1, 10);
        let n = g.usize_in(1, 2 * m + 3); // n < m shapes included
        let mut alive: Vec<bool> = (0..m).map(|_| g.bool()).collect();
        if g.bool() {
            // Force the 1-survivor edge regularly.
            alive.iter_mut().for_each(|a| *a = false);
        }
        alive[g.usize_in(0, m - 1)] = true;
        let aset = AliveSet::with_alive(alive.clone());
        let topos = [
            Topology::ring(m),
            Topology::tree(m),
            Topology::hier(m, g.usize_in(1, m)),
        ];
        for topo in topos {
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 5.0)).collect();
            let refs: Vec<&[f32]> =
                aset.members().iter().map(|&w| inputs[w].as_slice()).collect();
            let want = vecmath::mean(&refs);
            let mut bufs = inputs.clone();
            let mut scratch = ReduceScratch::default();
            topo.allreduce_mean_alive_with(&mut bufs, &aset, &mut scratch);
            for w in 0..m {
                if aset.is_member(w) {
                    assert_close(&bufs[w], &want, 1e-4, 1e-5);
                } else {
                    for (a, b) in bufs[w].iter().zip(&inputs[w]) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "dead buffer touched ({:?}, m={m})",
                            topo.kind
                        );
                    }
                }
            }
        }
    });
}

/// One scratch across many masked shapes: reuse must never change a bit
/// relative to fresh scratch (the pooled communicator-thread contract).
#[test]
fn property_masked_reduce_scratch_reuse_is_bit_identical() {
    let reused = std::cell::RefCell::new(ReduceScratch::default());
    property("masked reduce scratch reuse", 60, |g| {
        let m = g.usize_in(2, 8);
        let n = g.usize_in(1, 40);
        let mut alive: Vec<bool> = (0..m).map(|_| g.bool()).collect();
        alive[g.usize_in(0, m - 1)] = true;
        let aset = AliveSet::with_alive(alive);
        for topo in [Topology::ring(m), Topology::tree(m), Topology::hier(m, 2)] {
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 4.0)).collect();
            let mut fresh = inputs.clone();
            topo.allreduce_mean_alive_with(&mut fresh, &aset, &mut ReduceScratch::default());
            let mut warm = inputs;
            topo.allreduce_mean_alive_with(&mut warm, &aset, &mut reused.borrow_mut());
            for (a, b) in fresh.iter().zip(&warm) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{:?} m={m} n={n}", topo.kind);
                }
            }
        }
    });
}

/// Sampled-cohort framing of the masked reduces (DESIGN.md §14): over an
/// *arbitrary* cohort drawn with `Gen::subset`, the ring/tree/hier alive
/// reduces are exactly mean-preserving over the cohort and leave every
/// non-participant's buffer bit-untouched — and whenever the drawn cohort
/// happens to be the full population, the masked entry point must be
/// *bit-identical* to the dense reduce. That last equality is the seam the
/// population axis rides: an N == k run takes the dense path every round,
/// so its golden digests cannot move.
#[test]
fn property_sampled_cohort_reduces_are_exact_and_dense_on_full_cohort() {
    property("sampled-cohort reduce == cohort mean / dense", 120, |g| {
        let m = g.usize_in(2, 12);
        let n = g.usize_in(1, 2 * m + 3); // n < m chunking shapes included
        let all: Vec<usize> = (0..m).collect();
        // A dense keep probability makes the cohort == population case a
        // routine draw, not a corner.
        let mut cohort = g.subset(&all, 0.8);
        if cohort.is_empty() {
            cohort.push(g.usize_in(0, m - 1));
        }
        let full = cohort.len() == m;
        let mut alive = vec![false; m];
        for &w in &cohort {
            alive[w] = true;
        }
        let aset = AliveSet::with_alive(alive);
        let topos = [
            Topology::ring(m),
            Topology::tree(m),
            Topology::hier(m, g.usize_in(1, m)),
        ];
        for topo in topos {
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 5.0)).collect();
            let mut masked = inputs.clone();
            topo.allreduce_mean_alive_with(&mut masked, &aset, &mut ReduceScratch::default());
            let refs: Vec<&[f32]> = cohort.iter().map(|&w| inputs[w].as_slice()).collect();
            let want = vecmath::mean(&refs);
            for &w in &cohort {
                assert_close(&masked[w], &want, 1e-4, 1e-5);
            }
            if full {
                let mut dense = inputs.clone();
                topo.allreduce_mean_with(&mut dense, &mut ReduceScratch::default());
                for (a, b) in masked.iter().zip(&dense) {
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "full cohort must take the dense path bit-for-bit ({:?}, m={m})",
                            topo.kind
                        );
                    }
                }
            } else {
                for w in 0..m {
                    if !aset.is_member(w) {
                        for (a, b) in masked[w].iter().zip(&inputs[w]) {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "non-participant buffer touched ({:?}, m={m})",
                                topo.kind
                            );
                        }
                    }
                }
            }
        }
    });
}

/// The masked de-biased gossip mix conserves survivor mass (values and
/// push-sum weights) per partition component, zeroes dead rows, and keeps
/// the de-biased consensus fixed point exact.
#[test]
fn property_masked_gossip_mix_conserves_survivor_mass() {
    property("masked push-sum conserves survivor mass", 100, |g| {
        let m = g.usize_in(2, 12);
        let k = g.usize_in(1, m - 1);
        let topo = Topology::gossip(m, k, g.rng().next_u64()).unwrap();
        let n = g.usize_in(1, 24);
        let mut alive: Vec<bool> = (0..m).map(|_| g.bool()).collect();
        alive[g.usize_in(0, m - 1)] = true;
        let aset = if g.bool() {
            let comp: Vec<usize> = (0..m).map(|_| g.usize_in(0, 1)).collect();
            AliveSet::with_partition(alive.clone(), comp)
        } else {
            AliveSet::with_alive(alive.clone())
        };
        let values: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 3.0)).collect();
        let weights = vec![1.0f64; m];
        let (out, w_out) = topo.gossip_mix_alive(&values, &weights, &aset);
        // Survivor mass (per dimension) and total push-sum weight conserved.
        for d in 0..n {
            let before: f64 = (0..m)
                .filter(|&j| aset.is_alive(j))
                .map(|j| values[j][d] as f64)
                .sum();
            let after: f64 = (0..m).map(|i| out[i][d] as f64).sum();
            assert!(
                (before - after).abs() <= 1e-3 * (1.0 + before.abs()),
                "mass leaked at dim {d}: {before} -> {after} (m={m}, k={k})"
            );
        }
        let alive_n = alive.iter().filter(|&&a| a).count() as f64;
        let total_w: f64 = w_out.iter().sum();
        // Shares are f32 (1/(1+deg)), so each sender's outgoing weight sums
        // to 1 only up to f32 rounding — a few ulps per worker.
        assert!(
            (total_w - alive_n).abs() < 1e-5 * alive_n.max(1.0),
            "push-sum weight leaked: {total_w} vs {alive_n}"
        );
        // Dead rows receive exactly nothing.
        for i in 0..m {
            if !aset.is_alive(i) {
                assert_eq!(w_out[i], 0.0, "dead worker {i} got weight");
                assert!(out[i].iter().all(|&x| x == 0.0), "dead worker {i} got mass");
            }
        }
    });
}

/// Deterministic edges of the masked gossip mix: the consensus fixed point
/// survives de-biasing bit-exactly on the 1-survivor edge, and within f32
/// tolerance on a general masked round.
#[test]
fn masked_gossip_debias_fixed_point_and_single_survivor() {
    let topo = Topology::gossip(6, 2, 3).unwrap();
    // Consensus: every live worker holds the same vector; the de-biased
    // estimate must return it (value/weight cancels the shares).
    let mut alive = vec![true; 6];
    alive[1] = false;
    alive[4] = false;
    let aset = AliveSet::with_alive(alive);
    let c: Vec<f32> = (0..5).map(|i| i as f32 * 0.7 - 1.0).collect();
    let values: Vec<Vec<f32>> = (0..6).map(|_| c.clone()).collect();
    let weights = vec![1.0f64; 6];
    let (out, w_out) = topo.gossip_mix_alive(&values, &weights, &aset);
    for i in [0usize, 2, 3, 5] {
        assert!(w_out[i] > 0.0);
        let est: Vec<f32> = out[i].iter().map(|&x| x / w_out[i] as f32).collect();
        assert_close(&est, &c, 1e-5, 1e-6);
    }
    // Single survivor: no live edges, share = 1 — bit-exact passthrough.
    let mut alive = vec![false; 6];
    alive[2] = true;
    let aset = AliveSet::with_alive(alive);
    let (out, w_out) = topo.gossip_mix_alive(&values, &weights, &aset);
    assert_eq!(w_out[2], 1.0);
    for (a, b) in out[2].iter().zip(&c) {
        assert_eq!(a.to_bits(), b.to_bits(), "single survivor must keep its value");
    }
}

/// Same axis on the non-blocking family: with a slow node, hetero-τ reduces
/// the collective's late start, so the overlapped run blocks less and ends
/// sooner.
#[test]
fn slow_node_with_hetero_tau_speeds_up_overlap() {
    let mut uni = ExperimentConfig::default();
    uni.workers = 4;
    uni.epochs = 8.0;
    uni.train_n = 512;
    uni.test_n = 100;
    uni.tau = 4;
    uni.algo = Algo::OverlapM;
    uni.straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };
    let mut het = uni.clone();
    het.tau_hetero = true;

    let lu = native_run(&uni);
    let lh = native_run(&het);
    assert!(
        lh.total_sim_time < lu.total_sim_time,
        "hetero-τ must shorten the straggled overlap run: {} vs {}",
        lh.total_sim_time,
        lu.total_sim_time
    );
}
