//! Failure injection + degraded-cluster scenarios: the system must fail
//! loudly and helpfully on malformed inputs (corrupted artifacts, shape
//! mismatches, bad configs), and must *degrade gracefully* on hostile
//! clusters — slow nodes, τ larger than the run, single-worker clusters —
//! including the new scenario axes (heterogeneous τ, adaptive τ).
//!
//! Artifact-free by default (native backend); the tests that exercise the
//! PJRT artifact loader are gated on the `pjrt` feature.

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::runtime::manifest::Manifest;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;

#[cfg(feature = "pjrt")]
#[test]
fn missing_artifacts_dir_is_a_clear_error() {
    use std::path::Path;
    let msg = match olsgd::runtime::Runtime::new(Path::new("/nonexistent/artifacts")) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for missing artifacts dir"),
    };
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[cfg(feature = "pjrt")]
#[test]
fn unknown_model_is_rejected() {
    use std::path::Path;
    let runtime = olsgd::runtime::Runtime::new(Path::new("artifacts")).unwrap();
    let msg = match runtime.load_model("resnet152") {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for unknown model"),
    };
    assert!(msg.contains("not in manifest"));
}

#[test]
fn corrupted_manifest_is_rejected() {
    for bad in [
        "",                                  // empty
        "{",                                 // truncated
        r#"{"image_shape": [32, 32]}"#,      // wrong rank
        r#"{"image_shape": [32,32,3], "num_classes": 10}"#, // missing keys
        r#"{"image_shape": [32,32,3], "num_classes": 10,
            "train_batch": 32, "eval_batch": 100,
            "models": {"x": {"param_count": "ten", "tensors": [], "modules": {}}}}"#,
    ] {
        assert!(Manifest::parse(bad).is_err(), "accepted corrupt manifest: {bad:?}");
    }
}

#[test]
fn wrong_input_lengths_error_not_panic() {
    let m = ModelRuntime::native("linear").unwrap();
    let short = vec![0.0f32; m.n - 1];
    let ok_mom = vec![0.0f32; m.n];
    let images = vec![0.0f32; m.train_batch * 32 * 32 * 3];
    let labels = vec![0i32; m.train_batch];
    assert!(m.train_step(&short, &ok_mom, &images, &labels, 0.1, 0.9, 0.0).is_err());
    assert!(m.grad_step(&short, &images, &labels).is_err());
    // wrong batch
    let bad_imgs = vec![0.0f32; (m.train_batch - 1) * 32 * 32 * 3];
    let okp = vec![0.0f32; m.n];
    assert!(m.grad_step(&okp, &bad_imgs, &labels).is_err());
    // eval set not a multiple of eval batch
    let imgs = vec![0.0f32; 7 * 32 * 32 * 3];
    let lbl = vec![0i32; 7];
    assert!(m.evaluate_set(&okp, &imgs, &lbl).is_err());
}

#[test]
fn config_rejects_nonsense() {
    let mut c = ExperimentConfig::default();
    assert!(c.set("algo", "sgdx").is_err());
    assert!(c.set("tau", "-3").is_err());
    assert!(c.set("epochs", "many").is_err());
    assert!(c.set("straggler", "quantum:2").is_err());
    assert!(c.set("tau_min", "1.5").is_err());
    assert!(c.set("tau_hetero", "maybe").is_err());
    assert!(c.set("ada_patience", "-1").is_err());
    assert!(c.set("net", "infiniband").is_ok()); // stored...
    assert!(c.network().is_err()); // ...but rejected at use
    assert!(c.set("topology", "hypercube").is_ok()); // stored...
    assert!(c.topology().is_err()); // ...but rejected at use
    assert!(c.set("gossip_degree", "-2").is_err());
    assert!(c.set("hier_groups", "two").is_err());
}

fn native_run(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

#[test]
fn degenerate_single_worker_runs() {
    // m=1: all collectives are free no-ops; every algorithm must still work
    // (overlap-gossip included: its graph degenerates to the empty graph).
    for algo in [Algo::Sync, Algo::OverlapM, Algo::OverlapAda, Algo::OverlapGossip, Algo::Cocod] {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 1;
        cfg.epochs = 1.0;
        cfg.train_n = 64;
        cfg.test_n = 100;
        cfg.algo = algo;
        let log = native_run(&cfg);
        assert!(log.final_loss().is_finite(), "{algo:?} failed with m=1");
        assert_eq!(log.total_idle_s, 0.0);
    }
}

#[test]
fn tau_larger_than_total_steps_degrades_gracefully() {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 2;
    cfg.epochs = 1.0; // 2 steps per worker
    cfg.train_n = 128;
    cfg.test_n = 100;
    cfg.tau = 1000; // way beyond the run
    cfg.algo = Algo::OverlapM;
    let log = native_run(&cfg);
    assert!(log.steps > 0 && log.final_loss().is_finite());
}

#[test]
fn hetero_tau_degenerates_to_uniform_without_stragglers() {
    // No straggler -> all observed rates equal -> the hetero plan must not
    // change the schedule (identical digests).
    let mut uni = ExperimentConfig::default();
    uni.workers = 4;
    uni.epochs = 4.0;
    uni.train_n = 512;
    uni.test_n = 100;
    uni.tau = 4;
    uni.algo = Algo::Local;
    let mut het = uni.clone();
    het.tau_hetero = true;
    let a = native_run(&uni);
    let b = native_run(&het);
    assert_eq!(a.digest(), b.digest(), "hetero-τ must be a no-op on a uniform cluster");
}

/// E9 — the straggler claim, new scenario axis: a `SlowNode` cluster with
/// heterogeneous τ must show (much) less idle time than with uniform τ,
/// because the slow node runs proportionally fewer local steps per round
/// and everyone reaches the blocking boundary at ≈ the same virtual time.
#[test]
fn slow_node_with_hetero_tau_idles_less_than_uniform_tau() {
    let mut uni = ExperimentConfig::default();
    uni.workers = 4;
    uni.epochs = 8.0; // 4 steps/epoch at train_n=512/m=4/b=32 -> 8 rounds of τ=4
    uni.train_n = 512;
    uni.test_n = 100;
    uni.tau = 4;
    uni.algo = Algo::Local;
    uni.straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };
    let mut het = uni.clone();
    het.tau_hetero = true;

    let lu = native_run(&uni);
    let lh = native_run(&het);
    assert!(lu.total_idle_s > 0.0, "uniform τ must idle at the barrier");
    assert!(
        lh.total_idle_s < 0.5 * lu.total_idle_s,
        "hetero-τ did not mitigate the straggler: idle {} vs uniform {}",
        lh.total_idle_s,
        lu.total_idle_s
    );
    // Mitigation also shows up as wall-clock: the hetero run finishes sooner.
    assert!(lh.total_sim_time < lu.total_sim_time);
    assert!(lh.final_loss().is_finite());
}

/// Same axis on the non-blocking family: with a slow node, hetero-τ reduces
/// the collective's late start, so the overlapped run blocks less and ends
/// sooner.
#[test]
fn slow_node_with_hetero_tau_speeds_up_overlap() {
    let mut uni = ExperimentConfig::default();
    uni.workers = 4;
    uni.epochs = 8.0;
    uni.train_n = 512;
    uni.test_n = 100;
    uni.tau = 4;
    uni.algo = Algo::OverlapM;
    uni.straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };
    let mut het = uni.clone();
    het.tau_hetero = true;

    let lu = native_run(&uni);
    let lh = native_run(&het);
    assert!(
        lh.total_sim_time < lu.total_sim_time,
        "hetero-τ must shorten the straggled overlap run: {} vs {}",
        lh.total_sim_time,
        lu.total_sim_time
    );
}
