//! Failure injection: the system must fail loudly and helpfully, never
//! silently — corrupted artifacts, shape mismatches, bad configs, and
//! degenerate workloads.

use std::path::Path;

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::runtime::manifest::Manifest;
use olsgd::runtime::Runtime;

#[test]
fn missing_artifacts_dir_is_a_clear_error() {
    let msg = match Runtime::new(Path::new("/nonexistent/artifacts")) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for missing artifacts dir"),
    };
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupted_manifest_is_rejected() {
    for bad in [
        "",                                  // empty
        "{",                                 // truncated
        r#"{"image_shape": [32, 32]}"#,      // wrong rank
        r#"{"image_shape": [32,32,3], "num_classes": 10}"#, // missing keys
        r#"{"image_shape": [32,32,3], "num_classes": 10,
            "train_batch": 32, "eval_batch": 100,
            "models": {"x": {"param_count": "ten", "tensors": [], "modules": {}}}}"#,
    ] {
        assert!(Manifest::parse(bad).is_err(), "accepted corrupt manifest: {bad:?}");
    }
}

#[test]
fn wrong_input_lengths_error_not_panic() {
    let runtime = Runtime::new(Path::new("artifacts")).expect("make artifacts first");
    let m = runtime.load_model("cnn").unwrap();
    let short = vec![0.0f32; m.n - 1];
    let ok_mom = vec![0.0f32; m.n];
    let images = vec![0.0f32; m.train_batch * 32 * 32 * 3];
    let labels = vec![0i32; m.train_batch];
    assert!(m.train_step(&short, &ok_mom, &images, &labels, 0.1, 0.9, 0.0).is_err());
    assert!(m.grad_step(&short, &images, &labels).is_err());
    // wrong batch
    let bad_imgs = vec![0.0f32; (m.train_batch - 1) * 32 * 32 * 3];
    let okp = vec![0.0f32; m.n];
    assert!(m.grad_step(&okp, &bad_imgs, &labels).is_err());
    // eval set not a multiple of eval batch
    let imgs = vec![0.0f32; 7 * 32 * 32 * 3];
    let lbl = vec![0i32; 7];
    assert!(m.evaluate_set(&okp, &imgs, &lbl).is_err());
}

#[test]
fn unknown_model_is_rejected() {
    let runtime = Runtime::new(Path::new("artifacts")).unwrap();
    let msg = match runtime.load_model("resnet152") {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for unknown model"),
    };
    assert!(msg.contains("not in manifest"));
}

#[test]
fn config_rejects_nonsense() {
    let mut c = ExperimentConfig::default();
    assert!(c.set("algo", "sgdx").is_err());
    assert!(c.set("tau", "-3").is_err());
    assert!(c.set("epochs", "many").is_err());
    assert!(c.set("straggler", "quantum:2").is_err());
    assert!(c.set("net", "infiniband").is_ok()); // stored...
    assert!(c.network().is_err()); // ...but rejected at use
}

#[test]
fn degenerate_single_worker_runs() {
    // m=1: all collectives are free no-ops; every algorithm must still work.
    let runtime = Runtime::new(Path::new("artifacts")).unwrap();
    let rt = runtime.load_model("cnn").unwrap();
    let gen = olsgd::data::GenConfig::default();
    let train = olsgd::data::generate(1, 64, "train", &gen);
    let test = olsgd::data::generate(1, 100, "test", &gen);
    for algo in [Algo::Sync, Algo::OverlapM, Algo::Cocod] {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 1;
        cfg.epochs = 1.0;
        cfg.train_n = 64;
        cfg.test_n = 100;
        cfg.algo = algo;
        let log = olsgd::coordinator::run_experiment(&rt, &cfg, &train, &test).unwrap();
        assert!(log.final_loss().is_finite(), "{algo:?} failed with m=1");
        assert_eq!(log.total_idle_s, 0.0);
    }
}

#[test]
fn tau_larger_than_total_steps_degrades_gracefully() {
    let runtime = Runtime::new(Path::new("artifacts")).unwrap();
    let rt = runtime.load_model("cnn").unwrap();
    let gen = olsgd::data::GenConfig::default();
    let train = olsgd::data::generate(1, 128, "train", &gen);
    let test = olsgd::data::generate(1, 100, "test", &gen);
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 2;
    cfg.epochs = 1.0; // 2 steps per worker
    cfg.train_n = 128;
    cfg.test_n = 100;
    cfg.tau = 1000; // way beyond the run
    cfg.algo = Algo::OverlapM;
    let log = olsgd::coordinator::run_experiment(&rt, &cfg, &train, &test).unwrap();
    assert!(log.steps > 0 && log.final_loss().is_finite());
}
