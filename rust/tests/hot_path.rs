//! Hot-path regression locks (DESIGN.md §10, EXPERIMENTS.md E13): after
//! the warm-up rounds, a training run must spawn **zero** OS threads and
//! perform **zero** tracked hot-path allocations per round — the persistent
//! worker pool and the collective buffer pool contract — while staying
//! bit-identical to the sim backend on the m = 16 paper cluster shape.
//!
//! The counters come from `TrainLog::hot` (tracked by the executor and the
//! buffer pool); they are reporting-only and never enter the digest, which
//! `rust/src/metrics` unit tests pin separately.

use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;

/// m = 16 paper cluster shape, 4 rounds at τ = 2 (2 warm-up + 2 steady),
/// jitter stragglers so the per-worker RNG streams are live under true
/// concurrency.
fn paper16_cfg(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 16;
    cfg.train_n = 16 * 64; // 64/shard -> 2 steps/epoch
    cfg.test_n = 100;
    cfg.epochs = 4.0; // 8 global steps -> 4 rounds at tau = 2
    cfg.eval_every = 2.0;
    cfg.tau = 2;
    cfg.algo = algo;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg
}

fn run_pair(cfg: &ExperimentConfig) -> (TrainLog, TrainLog) {
    let rt = ModelRuntime::native_with(&cfg.model, cfg.hidden, cfg.kernels).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let mut sim_cfg = cfg.clone();
    sim_cfg.execution = Execution::Sim;
    let sim = run_experiment(&rt, &sim_cfg, &train, &test).unwrap();
    let mut thr_cfg = cfg.clone();
    thr_cfg.execution = Execution::Threads;
    let thr = run_experiment(&rt, &thr_cfg, &train, &test).unwrap();
    (sim, thr)
}

/// The headline lock: overlap-m on the threads backend spawns exactly the
/// pool (m + 1 threads, once), allocates collective buffers only during
/// the two warm-up rounds, and is digest-identical to sim.
#[test]
fn overlap_m_threads_steady_state_is_spawn_and_alloc_free() {
    let cfg = paper16_cfg(Algo::OverlapM);
    let (sim, thr) = run_pair(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "pooled path drifted from sim at m=16");

    assert_eq!(thr.hot.rounds, 4, "shape drifted: steady window needs rounds after warm-up");
    assert_eq!(thr.hot.warmup_rounds, 2);
    assert_eq!(
        thr.hot.thread_spawns_total, 17,
        "the pool spawns m + 1 = 17 threads, once"
    );
    assert_eq!(thr.hot.steady_thread_spawns, 0, "no spawns after warm-up");
    // One collective launch per round needs m snapshot buffers + 1 outer
    // shell; only round 1 may allocate them.
    assert_eq!(
        thr.hot.buffer_allocs_total, 17,
        "warm-up must allocate exactly one snapshot set (m + 1 tracked allocs)"
    );
    assert_eq!(thr.hot.steady_buffer_allocs, 0, "steady rounds must recycle");
    assert_eq!(thr.hot.steady_buffer_alloc_bytes, 0);
    assert!(thr.hot.buffer_hits_total > 0, "recycling must actually happen");

    // Sim shares the buffer-pool discipline and never spawns.
    assert_eq!(sim.hot.thread_spawns_total, 0);
    assert_eq!(sim.hot.steady_buffer_allocs, 0);
    assert_eq!(sim.hot.buffer_allocs_total, 17);
}

/// The same lock for the other pooled launchers: CoCoD (launches in
/// `before_local`) and the decentralized gossip exchange (two pooled sets
/// per round).
#[test]
fn cocod_and_gossip_threads_steady_state_is_spawn_and_alloc_free() {
    for algo in [Algo::Cocod, Algo::OverlapGossip] {
        let cfg = paper16_cfg(algo);
        let (sim, thr) = run_pair(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{algo:?}: pooled path drifted from sim");
        assert_eq!(thr.hot.thread_spawns_total, 17, "{algo:?}");
        assert_eq!(thr.hot.steady_thread_spawns, 0, "{algo:?}");
        assert_eq!(thr.hot.steady_buffer_allocs, 0, "{algo:?}");
        assert_eq!(thr.hot.steady_buffer_alloc_bytes, 0, "{algo:?}");
        assert!(thr.hot.buffer_allocs_total > 0, "{algo:?}: warm-up must prime the pool");
        assert!(thr.hot.buffer_hits_total > 0, "{algo:?}: recycling must actually happen");
        assert_eq!(sim.hot.steady_buffer_allocs, 0, "{algo:?}");
    }
}

/// Blocking schedules reduce inline over the executor scratch: they touch
/// the buffer pool only where they route an average through it (elastic),
/// and their steady windows are equally clean.
#[test]
fn blocking_schedules_are_clean_too() {
    for algo in [Algo::Sync, Algo::Local, Algo::Eamsgd] {
        let mut cfg = paper16_cfg(algo);
        if algo == Algo::Sync {
            cfg.tau = 1; // sync advances one step per round
            cfg.epochs = 2.0; // keep it quick: 4 rounds
        }
        let (sim, thr) = run_pair(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{algo:?}: threads drifted from sim");
        assert_eq!(thr.hot.thread_spawns_total, 17, "{algo:?}");
        assert_eq!(thr.hot.steady_thread_spawns, 0, "{algo:?}");
        assert_eq!(thr.hot.steady_buffer_allocs, 0, "{algo:?}");
        if algo == Algo::Sync || algo == Algo::Local {
            assert_eq!(
                thr.hot.buffer_allocs_total, 0,
                "{algo:?}: inline reduces must not touch the buffer pool"
            );
        }
    }
}

/// Hetero-τ and the adaptive controller change the *plan*, not the memory
/// discipline: pooled launches must stay steady-clean when per-worker step
/// counts vary round to round.
#[test]
fn steady_state_survives_heterogeneous_plans() {
    let mut cfg = paper16_cfg(Algo::OverlapM);
    cfg.tau_hetero = true;
    cfg.straggler = StragglerModel::SlowNode { node: 3, factor: 3.0 };
    let (sim, thr) = run_pair(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "hetero-τ pooled path drifted");
    assert_eq!(thr.hot.steady_thread_spawns, 0);
    assert_eq!(thr.hot.steady_buffer_allocs, 0);
}

/// Fault rounds ride the same memory discipline (DESIGN.md §11): a crash
/// parks the worker's pool thread (never respawns it), the masked
/// collective takes a *smaller* pooled snapshot (pure free-list hits), and
/// the rejoin warm start copies in place — so crash/rejoin rounds after
/// warm-up introduce zero steady-state spawns and zero tracked allocs,
/// while staying digest-identical across backends.
#[test]
fn crash_and_rejoin_rounds_stay_spawn_and_alloc_free() {
    for algo in [Algo::OverlapM, Algo::Cocod, Algo::OverlapGossip] {
        let mut cfg = paper16_cfg(algo);
        cfg.epochs = 6.0; // 12 global steps -> 6 rounds: 2 warm-up + 4 steady
        cfg.set("fault", "crash@4:3;rejoin@5:3").unwrap();
        let (sim, thr) = run_pair(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{algo:?}: faulted run drifted from sim");
        assert_eq!(thr.hot.rounds, 6, "{algo:?}: shape drifted");
        assert_eq!(
            thr.hot.thread_spawns_total, 17,
            "{algo:?}: the pool must never respawn a crashed worker's thread"
        );
        assert_eq!(thr.hot.steady_thread_spawns, 0, "{algo:?}");
        assert_eq!(
            thr.hot.steady_buffer_allocs, 0,
            "{algo:?}: masked collectives must recycle, not allocate"
        );
        assert_eq!(thr.hot.steady_buffer_alloc_bytes, 0, "{algo:?}");
        assert!(thr.hot.buffer_hits_total > 0, "{algo:?}");
        assert_eq!(thr.survivors, vec![(4, 15), (5, 16)], "{algo:?}");
        assert_eq!(thr.fault_trace.len(), 2, "{algo:?}");
    }
}

/// The population axis rides the same memory discipline (DESIGN.md §14):
/// cohort binding is pure `mem::swap` against recycled state shells, the
/// LRU store and its spill never touch the tracked buffer pool, and the
/// pool threads are slot-bound machines that persist across re-binds — so
/// a churning sampled run (N > k, every round re-binding slots, reserve 0
/// forcing spill traffic) must stay at zero steady-state spawns and zero
/// tracked allocs, digest-equal across backends. The N == k leg must
/// additionally reproduce the dense run's counters exactly.
#[test]
fn sampled_rounds_stay_spawn_and_alloc_free() {
    // N == k: bit-identical engine path, bit-identical counters.
    let dense = paper16_cfg(Algo::OverlapM);
    let (_, dense_thr) = run_pair(&dense);
    let mut nk = paper16_cfg(Algo::OverlapM);
    nk.set("population", "16").unwrap();
    nk.set("sample_k", "16").unwrap();
    let (sim, thr) = run_pair(&nk);
    assert_eq!(sim.digest(), thr.digest(), "N == k drifted across backends");
    assert_eq!(thr.hot, dense_thr.hot, "N == k must not change the memory discipline");
    assert_eq!(thr.hot.steady_thread_spawns, 0);
    assert_eq!(thr.hot.steady_buffer_allocs, 0);

    // N > k with maximal churn pressure: reserve 0 spills every unbind.
    for algo in [Algo::OverlapM, Algo::Cocod, Algo::OverlapGossip] {
        let mut cfg = paper16_cfg(algo);
        cfg.epochs = 6.0; // 12 global steps -> 6 rounds: 2 warm-up + 4 steady
        cfg.set("population", "64").unwrap();
        cfg.set("sample_k", "16").unwrap();
        cfg.set("sample_reserve", "0").unwrap();
        let (sim, thr) = run_pair(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{algo:?}: sampled run drifted from sim");
        assert_eq!(thr.hot.rounds, 6, "{algo:?}: shape drifted");
        assert_eq!(
            thr.hot.thread_spawns_total, 17,
            "{algo:?}: re-binding a slot must never respawn its pool thread"
        );
        assert_eq!(thr.hot.steady_thread_spawns, 0, "{algo:?}");
        assert_eq!(
            thr.hot.steady_buffer_allocs, 0,
            "{algo:?}: cohort binding must not touch the tracked buffer pool"
        );
        assert_eq!(thr.hot.steady_buffer_alloc_bytes, 0, "{algo:?}");
        let c = thr.population.expect("sampled run must report population counters");
        assert!(c.evictions > 0, "{algo:?}: reserve 0 under churn must spill");
        assert_eq!(c.resident_workers_max, 16, "{algo:?}: only the k bound states");
    }
}

/// The SIMD tier and the MLP backend ride the same memory discipline
/// (DESIGN.md §15): the SIMD kernels allocate nothing (fixed-lane loops
/// over caller buffers), the MLP's scratch is thread-local and grow-once,
/// and — because every SIMD kernel is bit-identical to scalar by
/// construction — the tier must not move the digest at all: all four
/// (model=mlp) runs here, scalar/simd × sim/threads, share one digest.
#[test]
fn mlp_simd_tier_keeps_the_steady_state_clean_and_the_digest_fixed() {
    let mut scalar_cfg = paper16_cfg(Algo::OverlapM);
    scalar_cfg.set("model", "mlp").unwrap();
    scalar_cfg.set("hidden", "32").unwrap();
    let mut simd_cfg = scalar_cfg.clone();
    simd_cfg.set("kernels", "simd").unwrap();

    let (scalar_sim, scalar_thr) = run_pair(&scalar_cfg);
    let (simd_sim, simd_thr) = run_pair(&simd_cfg);
    assert_eq!(scalar_sim.digest(), scalar_thr.digest(), "mlp scalar drifted across backends");
    assert_eq!(simd_sim.digest(), simd_thr.digest(), "mlp simd drifted across backends");
    assert_eq!(
        scalar_sim.digest(),
        simd_sim.digest(),
        "the SIMD tier moved the digest — a kernel reassociated its accumulation"
    );

    for (label, thr) in [("scalar", &scalar_thr), ("simd", &simd_thr)] {
        assert_eq!(thr.hot.thread_spawns_total, 17, "mlp/{label}");
        assert_eq!(thr.hot.steady_thread_spawns, 0, "mlp/{label}: no spawns after warm-up");
        assert_eq!(
            thr.hot.buffer_allocs_total, 17,
            "mlp/{label}: warm-up allocates exactly one snapshot set"
        );
        assert_eq!(
            thr.hot.steady_buffer_allocs, 0,
            "mlp/{label}: steady rounds must recycle — the MLP scratch is thread-local"
        );
        assert_eq!(thr.hot.steady_buffer_alloc_bytes, 0, "mlp/{label}");
        assert!(thr.hot.buffer_hits_total > 0, "mlp/{label}");
    }
}

/// Counters are pure reporting: two identical runs agree on them, and the
/// digest ignores them entirely (sim and threads share a digest while
/// reporting different spawn counts).
#[test]
fn counters_are_deterministic_and_digest_invisible() {
    let cfg = paper16_cfg(Algo::OverlapM);
    let (_, a) = run_pair(&cfg);
    let (_, b) = run_pair(&cfg);
    assert_eq!(a.hot, b.hot, "tracked counters must be deterministic");
    let (sim, thr) = run_pair(&cfg);
    assert_ne!(sim.hot.thread_spawns_total, thr.hot.thread_spawns_total);
    assert_eq!(sim.digest(), thr.digest());
}
