//! Round-plan hardening (the engine's schedule contract) + `hetero_plan`
//! invariants (paper §straggler mitigation, E9).
//!
//! The plan-coverage and grad-mode checks in `engine::run` were
//! `debug_assert!`s in the seed — release builds silently accepted ragged
//! `RoundPlan`s. They are hard `ensure!` errors now; the malformed-plan
//! tests below must fail the run in **both** profiles, which CI enforces by
//! running the suite under `cargo test` and `cargo test --release`.
//!
//! `hetero_plan`'s invariants, property-tested on synthetic engine state and
//! probed on real runs under `SlowNode` and `ShiftedExp` stragglers:
//!
//! * every worker's step count lands in `[1, advance]`;
//! * every fastest-measured worker receives the full `advance`;
//! * the per-worker *target* round durations (steps × measured rate) agree
//!   within 1.5× the slowest worker's per-step time — i.e. round-boundary
//!   virtual times stay within about one slowest-step of each other, which
//!   the `SlowNode` probe also verifies on the realized clocks.

use olsgd::clock::Clocks;
use olsgd::config::ExperimentConfig;
use olsgd::coordinator::engine::{
    self, hetero_plan, uniform_plan, Engine, LocalPhase, MixingStrategy, RoundOutcome, RoundPlan,
};
use olsgd::coordinator::{make_shards, TrainContext};
use olsgd::data::{self, Dataset, GenConfig};
use olsgd::optim::LrSchedule;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;
use olsgd::util::proptest::property;

type R<T> = anyhow::Result<T>;

/// Everything a `TrainContext` borrows, owned in one bundle per test.
struct Fixture {
    rt: ModelRuntime,
    cfg: ExperimentConfig,
    train: Dataset,
    test: Dataset,
}

impl Fixture {
    fn new(cfg: ExperimentConfig) -> Self {
        let rt = ModelRuntime::native("linear").unwrap();
        let gen = GenConfig::default();
        let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
        let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
        Self { rt, cfg, train, test }
    }

    /// Mirrors `coordinator::run_experiment`'s context assembly.
    fn ctx(&self) -> TrainContext<'_> {
        let shards = make_shards(&self.cfg, &self.train);
        let steps_per_epoch = (shards[0].len() / self.rt.train_batch).max(1);
        let cluster = self.cfg.cluster(self.rt.n * 4).unwrap();
        let schedule =
            LrSchedule::paper_scaled(self.cfg.base_lr, self.cfg.epochs, steps_per_epoch);
        TrainContext {
            rt: &self.rt,
            cfg: &self.cfg,
            cluster,
            schedule,
            train: &self.train,
            test: &self.test,
            shards,
        }
    }
}

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 4;
    cfg.train_n = 512; // 128/shard -> 4 steps/epoch
    cfg.test_n = 100;
    cfg.epochs = 2.0;
    cfg.eval_every = 2.0;
    cfg
}

// ---------------------------------------------------------------------------
// Malformed plans are hard errors (in debug AND release)
// ---------------------------------------------------------------------------

struct RaggedPlan;
impl MixingStrategy for RaggedPlan {
    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        RoundPlan { steps: vec![1; eng.workers.m + 1], advance: 1 }
    }
    fn mix(&mut self, _eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> R<()> {
        Ok(())
    }
}

struct ZeroAdvance;
impl MixingStrategy for ZeroAdvance {
    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        RoundPlan { steps: vec![0; eng.workers.m], advance: 0 }
    }
    fn mix(&mut self, _eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> R<()> {
        Ok(())
    }
}

struct OverAdvance;
impl MixingStrategy for OverAdvance {
    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        let too_far = eng.remaining() + 1;
        RoundPlan { steps: vec![1; eng.workers.m], advance: too_far }
    }
    fn mix(&mut self, _eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> R<()> {
        Ok(())
    }
}

struct StepsBeyondAdvance;
impl MixingStrategy for StepsBeyondAdvance {
    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        let mut steps = vec![1; eng.workers.m];
        steps[0] = 2; // > advance
        RoundPlan { steps, advance: 1 }
    }
    fn mix(&mut self, _eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> R<()> {
        Ok(())
    }
}

struct ZeroStepWorker;
impl MixingStrategy for ZeroStepWorker {
    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        let mut steps = vec![2; eng.workers.m];
        steps[1] = 0; // a silently-idle worker would corrupt the mix
        RoundPlan { steps, advance: 2 }
    }
    fn mix(&mut self, _eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> R<()> {
        Ok(())
    }
}

struct MultiStepGradRound;
impl MixingStrategy for MultiStepGradRound {
    fn phase(&self) -> LocalPhase {
        LocalPhase::GradOnly
    }
    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        RoundPlan { steps: vec![2; eng.workers.m], advance: 2 }
    }
    fn mix(&mut self, _eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> R<()> {
        Ok(())
    }
}

fn expect_malformed(err: anyhow::Error, what: &str) {
    let msg = format!("{err:#}");
    assert!(msg.contains("malformed RoundPlan"), "{what}: unhelpful error '{msg}'");
}

#[test]
fn malformed_plans_fail_the_run_in_every_profile() {
    // This test runs under whichever profile `cargo test` was invoked with;
    // CI invokes both, so a regression back to debug_assert! (which release
    // compiles out) cannot pass unnoticed.
    let f = Fixture::new(small_cfg());
    let ctx = f.ctx();
    expect_malformed(engine::run(&ctx, &mut RaggedPlan).unwrap_err(), "ragged");
    expect_malformed(engine::run(&ctx, &mut ZeroAdvance).unwrap_err(), "zero advance");
    expect_malformed(engine::run(&ctx, &mut OverAdvance).unwrap_err(), "over-advance");
    expect_malformed(
        engine::run(&ctx, &mut StepsBeyondAdvance).unwrap_err(),
        "steps beyond advance",
    );
    expect_malformed(engine::run(&ctx, &mut ZeroStepWorker).unwrap_err(), "zero-step worker");
    expect_malformed(
        engine::run(&ctx, &mut MultiStepGradRound).unwrap_err(),
        "multi-step grad round",
    );
    // Identical checks active regardless of debug assertions.
    let _profile_independent = cfg!(debug_assertions);
}

#[test]
fn well_formed_plans_still_run() {
    // The hardening must not reject the legitimate plans.
    let mut cfg = small_cfg();
    cfg.tau = 4;
    cfg.tau_hetero = true;
    cfg.straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };
    let f = Fixture::new(cfg);
    let log = engine::run(&f.ctx(), &mut BarrierProbe::new(4, 0.0, 0)).unwrap();
    assert_eq!(log.steps, 8);
}

// ---------------------------------------------------------------------------
// hetero_plan invariants — property-tested on synthetic engine state
// ---------------------------------------------------------------------------

/// Install synthetic measured rates into a fresh engine: worker `w` has
/// completed `done[w]` steps in `done[w] * rate[w]` compute seconds.
fn install_rates(eng: &mut Engine, done: &[usize], rates: &[f64]) {
    let m = eng.workers.m;
    eng.clocks = Clocks::new(m);
    eng.steps_done = done.to_vec();
    for w in 0..m {
        eng.clocks.compute(w, done[w] as f64 * rates[w]);
    }
}

fn check_plan_invariants(plan: &RoundPlan, rates: &[f64], tau: usize) {
    let m = rates.len();
    assert_eq!(plan.steps.len(), m);
    assert_eq!(plan.advance, tau, "advance is the nominal tau when remaining allows");
    let fastest = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    let slowest = rates.iter().cloned().fold(0.0f64, f64::max);
    for (w, &s) in plan.steps.iter().enumerate() {
        assert!(
            (1..=plan.advance).contains(&s),
            "worker {w}: steps {s} outside [1, {}]",
            plan.advance
        );
        if rates[w] == fastest {
            assert_eq!(s, plan.advance, "fastest worker {w} must get the full advance");
        }
    }
    // Target round durations agree within 1.5 slowest-steps (the rounding +
    // clamp-to-1 worst case; measured sup over 2·10^5 random rate vectors
    // is 1.0 slowest-steps).
    let durs: Vec<f64> = plan.steps.iter().zip(rates).map(|(&s, &r)| s as f64 * r).collect();
    let spread = durs.iter().cloned().fold(0.0f64, f64::max)
        - durs.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        spread <= 1.5 * slowest + 1e-9,
        "boundary spread {spread} exceeds 1.5 slowest-steps ({})",
        1.5 * slowest
    );
}

#[test]
fn property_hetero_plan_invariants_under_slow_node_and_shifted_exp_rates() {
    use std::cell::RefCell;
    let f = Fixture::new(small_cfg()); // m = 4 replicas back the engine
    let ctx = f.ctx();
    {
        let eng = RefCell::new(Engine::new(&ctx).expect("sim engine construction is infallible"));
        eng.borrow_mut().total = 1_000_000; // remaining never caps the plan
        let m = eng.borrow().workers.m;
        property("hetero_plan invariants", 300, |g| {
            let tau = g.usize_in(2, 16);
            let base = g.f64_in(0.05, 0.5);
            // Rate vectors from both straggler families: a deterministic
            // slow node, or per-worker shifted-exponential means.
            let slow_node = g.bool();
            let rates: Vec<f64> = (0..m)
                .map(|w| {
                    if slow_node {
                        if w == 0 {
                            base * g.f64_in(1.5, 4.0)
                        } else {
                            base
                        }
                    } else {
                        base * (1.0 + g.rng().next_exp(0.5))
                    }
                })
                .collect();
            let done: Vec<usize> = (0..m).map(|_| g.usize_in(1, 40)).collect();
            let mut eng = eng.borrow_mut();
            install_rates(&mut eng, &done, &rates);
            let plan = hetero_plan(&eng, tau);
            check_plan_invariants(&plan, &rates, tau);
        });

        // Unmeasured workers (steps_done = 0) fall back to the uniform plan.
        let mut eng = eng.borrow_mut();
        install_rates(&mut eng, &[3, 0, 3, 3], &[0.2; 4]);
        let plan = hetero_plan(&eng, 6);
        let uniform = uniform_plan(&eng, 6);
        assert_eq!(plan.steps, uniform.steps);
        assert_eq!(plan.advance, uniform.advance);
    }
}

// ---------------------------------------------------------------------------
// hetero_plan probed on real engine runs (E9 scenarios)
// ---------------------------------------------------------------------------

/// Barrier-style probe (a `local`-like schedule minus the averaging): plans
/// with `hetero_plan`, checks the invariants against the engine's real
/// measured rates each round, optionally checks the *realized* boundary lag,
/// then barriers like every blocking algorithm does.
struct BarrierProbe {
    tau: usize,
    /// assert realized boundary lag <= 1.5 * this (0.0 disables the check —
    /// realized lag is unbounded under stochastic stragglers)
    max_step_s: f64,
    rounds_seen: usize,
    checks: usize,
    skip_rounds: usize,
}

impl BarrierProbe {
    fn new(tau: usize, max_step_s: f64, skip_rounds: usize) -> Self {
        Self { tau, max_step_s, rounds_seen: 0, checks: 0, skip_rounds }
    }
}

impl MixingStrategy for BarrierProbe {
    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        let plan = hetero_plan(eng, self.tau);
        if eng.steps_done.iter().all(|&d| d > 0) && plan.advance == self.tau {
            let rates: Vec<f64> = (0..eng.workers.m)
                .map(|w| eng.clocks.worker(w).compute_s / eng.steps_done[w] as f64)
                .collect();
            check_plan_invariants(&plan, &rates, self.tau);
            self.checks += 1;
        }
        plan
    }

    fn mix(&mut self, eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> R<()> {
        self.rounds_seen += 1;
        if self.max_step_s > 0.0 && self.rounds_seen > self.skip_rounds {
            let lag = eng.clocks.lag();
            anyhow::ensure!(
                lag <= 1.5 * self.max_step_s + 1e-9,
                "round {}: realized boundary lag {lag} exceeds 1.5 slowest-steps ({})",
                self.rounds_seen,
                1.5 * self.max_step_s
            );
        }
        eng.clocks.barrier();
        Ok(())
    }
}

#[test]
fn slow_node_probe_keeps_round_boundaries_within_one_slowest_step() {
    let mut cfg = small_cfg();
    cfg.epochs = 8.0; // 32 steps -> 8 rounds at tau=4
    cfg.tau = 4;
    cfg.straggler = StragglerModel::SlowNode { node: 2, factor: 3.0 };
    let max_step_s = cfg.base_step_s * 3.0;
    let f = Fixture::new(cfg);
    // Round 1 is the uniform fallback (nothing measured yet): its lag is
    // the straggler gap by design, so the realized check skips it.
    let mut probe = BarrierProbe::new(4, max_step_s, 1);
    let log = engine::run(&f.ctx(), &mut probe).unwrap();
    assert_eq!(log.steps, 32);
    assert!(probe.checks >= 6, "probe must actually check plans: {}", probe.checks);
}

#[test]
fn shifted_exp_probe_keeps_plan_invariants() {
    let mut cfg = small_cfg();
    cfg.epochs = 8.0;
    cfg.tau = 4;
    cfg.straggler = StragglerModel::ShiftedExp { scale: 0.5 };
    let f = Fixture::new(cfg);
    // Realized lag is unbounded for stochastic stragglers; the plan
    // invariants (measured-rate targets) must still hold every round.
    let mut probe = BarrierProbe::new(4, 0.0, 0);
    let log = engine::run(&f.ctx(), &mut probe).unwrap();
    assert_eq!(log.steps, 32);
    assert!(probe.checks >= 6, "probe must actually check plans: {}", probe.checks);
}
