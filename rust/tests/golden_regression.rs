//! Golden regression: the round-engine refactor must be *behavior
//! preserving*, bit for bit.
//!
//! The seed's per-driver lockstep loops are preserved here verbatim as
//! reference implementations (built only from public coordinator API — the
//! pre-topology `ring_allreduce_mean` / `NetworkModel::allreduce_time`
//! path). For every algorithm we run a tiny fixed-seed config through BOTH
//! the engine (`coordinator::run`) and the reference loop and assert equal
//! [`TrainLog::digest`]s — covering the loss trace, eval records, virtual
//! timing (sim_time / compute / comm_blocked / idle), and byte accounting.
//! Future PRs that touch the engine cannot silently drift any observable.
//!
//! Because the references predate the `topology` subsystem, the same
//! assertion also locks the `collective/` refactor: on the default ring
//! topology every pre-existing algorithm's digest must stay bit-identical
//! to the legacy loops (ISSUE 2 acceptance). The new topology axis gets its
//! own fixed-seed digest locks below (`new_axis_digests_*`).

use olsgd::clock::Clocks;
use olsgd::collective::{ring_allreduce_mean, start_allreduce, NonBlockingAllReduce};
use olsgd::compress::PowerSgd;
use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::engine::PULLBACK_S;
use olsgd::coordinator::{make_shards, run_experiment, Recorder, TrainContext, Workers};
use olsgd::data::{self, Dataset, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::model::vecmath;
use olsgd::optim::LrSchedule;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;

type R<T> = anyhow::Result<T>;

fn make_ctx<'a>(
    rt: &'a ModelRuntime,
    cfg: &'a ExperimentConfig,
    train: &'a Dataset,
    test: &'a Dataset,
) -> TrainContext<'a> {
    // Mirrors coordinator::run_experiment's context assembly.
    let shards = make_shards(cfg, train);
    let steps_per_epoch = (shards[0].len() / rt.train_batch).max(1);
    let cluster = cfg.cluster(rt.n * 4).unwrap();
    let schedule = LrSchedule::paper_scaled(cfg.base_lr, cfg.epochs, steps_per_epoch);
    TrainContext { rt, cfg, cluster, schedule, train, test, shards }
}

// ---------------------------------------------------------------------------
// Reference drivers — the seed's lockstep loops, verbatim.
// ---------------------------------------------------------------------------

fn ref_sync(ctx: &TrainContext) -> R<TrainLog> {
    let m = ctx.cfg.workers;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();
    let comm_t = ctx.cluster.allreduce_time();

    for k in 0..total {
        let mut grads = Vec::with_capacity(m);
        let mut loss_sum = 0.0;
        for w in 0..m {
            let (loss, g) = workers.local_grad(w, ctx, &mut clocks)?;
            loss_sum += loss;
            grads.push(g);
        }
        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        ring_allreduce_mean(&mut grads);
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        let lr = ctx.schedule.lr_at_step(k);
        let (p, mom) = ctx.rt.sgd_update(
            &workers.params[0],
            &workers.mom[0],
            &grads[0],
            lr,
            ctx.cfg.mu,
            ctx.cfg.wd,
        )?;
        for w in 0..m {
            workers.params[w].copy_from_slice(&p);
            workers.mom[w].copy_from_slice(&mom);
        }

        rec.push_loss(k, loss_sum / m as f64);
        rec.maybe_eval(k + 1, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}

fn ref_powersgd(ctx: &TrainContext) -> R<TrainLog> {
    const GEMM_FLOPS: f64 = 5.0e12;

    let m = ctx.cfg.workers;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let mut psgd = PowerSgd::new(&ctx.rt.manifest, ctx.cfg.rank, m, ctx.cfg.seed);
    let total = ctx.total_steps();

    let full_bytes = ctx.rt.manifest.message_bytes();
    let frac = psgd.bytes_per_round() as f64 / full_bytes as f64;
    let scaled_bytes = (ctx.cluster.message_bytes as f64 * frac) as usize;
    let comm_t = ctx.cluster.net.allreduce_time(scaled_bytes, m);

    for k in 0..total {
        let mut grads = Vec::with_capacity(m);
        let mut loss_sum = 0.0;
        for w in 0..m {
            let (loss, g) = workers.local_grad(w, ctx, &mut clocks)?;
            loss_sum += loss;
            grads.push(g);
        }
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let out = psgd.round(&grad_refs);

        let enc_t =
            out.encode_flops * (full_bytes as f64 / (ctx.rt.n * 4) as f64).max(1.0) / GEMM_FLOPS;
        for w in 0..m {
            clocks.compute(w, enc_t);
        }
        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        rec.add_bytes((m * scaled_bytes) as u64);

        let lr = ctx.schedule.lr_at_step(k);
        let (p, mom) = ctx.rt.sgd_update(
            &workers.params[0],
            &workers.mom[0],
            &out.avg_grad,
            lr,
            ctx.cfg.mu,
            ctx.cfg.wd,
        )?;
        for w in 0..m {
            workers.params[w].copy_from_slice(&p);
            workers.mom[w].copy_from_slice(&mom);
        }

        rec.push_loss(k, loss_sum / m as f64);
        rec.maybe_eval(k + 1, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}

fn ref_local(ctx: &TrainContext) -> R<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();
    let comm_t = ctx.cluster.allreduce_time();

    let mut k = 0;
    while k < total {
        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        ring_allreduce_mean(&mut workers.params);
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}

fn ref_overlap(ctx: &TrainContext, beta: f32) -> R<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let alpha = ctx.cfg.alpha;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();

    let mut z = workers.params[0].clone();
    let mut v = vec![0.0f32; ctx.rt.n];
    let mut pending: Option<NonBlockingAllReduce> = None;

    let mut k = 0;
    while k < total {
        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        if let Some(h) = pending.take() {
            for w in 0..m {
                clocks.wait_comm_until(w, h.ready_at());
            }
            let (z2, v2) = ctx.rt.anchor_update(&z, &v, &h.result, beta)?;
            z = z2;
            v = v2;
        }

        for w in 0..m {
            workers.params[w] = ctx.rt.pullback(&workers.params[w], &z, alpha)?;
            clocks.compute(w, PULLBACK_S);
        }

        let start = (0..m).map(|w| clocks.now(w)).fold(0.0, f64::max);
        let refs: Vec<&[f32]> = workers.params.iter().map(|p| p.as_slice()).collect();
        pending = Some(start_allreduce(
            &refs,
            &ctx.cluster.net,
            ctx.cluster.message_bytes,
            start,
        ));
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}

fn ref_elastic(ctx: &TrainContext, mu: f32) -> R<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let alpha = ctx.cfg.alpha;
    let comm_t = ctx.cluster.allreduce_time();

    let mut cfg = ctx.cfg.clone();
    cfg.mu = mu;
    let ctx = TrainContext {
        rt: ctx.rt,
        cfg: &cfg,
        cluster: ctx.cluster.clone(),
        schedule: ctx.schedule.clone(),
        train: ctx.train,
        test: ctx.test,
        shards: ctx.shards.clone(),
    };
    let ctx = &ctx;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();

    let mut z = workers.params[0].clone();

    let mut k = 0;
    while k < total {
        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        let avg = workers.mean_params();
        for w in 0..m {
            vecmath::pullback_inplace(&mut workers.params[w], &z, alpha);
        }
        vecmath::axpby(alpha, &avg, 1.0 - alpha, &mut z);
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}

fn ref_cocod(ctx: &TrainContext) -> R<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();

    let mut snapshots: Vec<Vec<f32>> = workers.params.clone();

    let mut k = 0;
    while k < total {
        let pending: NonBlockingAllReduce = {
            let refs: Vec<&[f32]> = workers.params.iter().map(|p| p.as_slice()).collect();
            let start = (0..m).map(|w| clocks.now(w)).fold(0.0, f64::max);
            rec.add_bytes((m * ctx.cluster.message_bytes) as u64);
            snapshots.clone_from(&workers.params);
            start_allreduce(&refs, &ctx.cluster.net, ctx.cluster.message_bytes, start)
        };

        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        let h = pending;
        for w in 0..m {
            clocks.wait_comm_until(w, h.ready_at());
            let p = &mut workers.params[w];
            let snap = &snapshots[w];
            for i in 0..p.len() {
                p[i] = h.result[i] + (p[i] - snap[i]);
            }
        }

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}

// ---------------------------------------------------------------------------
// The golden assertions
// ---------------------------------------------------------------------------

fn golden_cfg(straggler: &StragglerModel) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 3;
    cfg.epochs = 2.0;
    cfg.train_n = 192; // 64/shard -> 2 steps/epoch -> 4 global steps
    cfg.test_n = 100;
    cfg.eval_every = 1.0;
    cfg.tau = 2;
    cfg.rank = 2;
    cfg.straggler = straggler.clone();
    cfg
}

fn reference_log(ctx: &TrainContext) -> TrainLog {
    match ctx.cfg.algo {
        Algo::Sync => ref_sync(ctx),
        Algo::PowerSgd => ref_powersgd(ctx),
        Algo::Local => ref_local(ctx),
        Algo::Overlap => ref_overlap(ctx, 0.0),
        Algo::OverlapM => ref_overlap(ctx, ctx.cfg.beta),
        Algo::Easgd => ref_elastic(ctx, 0.0),
        Algo::Eamsgd => ref_elastic(ctx, ctx.cfg.mu),
        Algo::Cocod => ref_cocod(ctx),
        Algo::OverlapAda | Algo::OverlapGossip => {
            unreachable!("new axis; no legacy reference")
        }
    }
    .unwrap()
}

#[test]
fn engine_matches_legacy_lockstep_loops_for_all_eight_algorithms() {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let legacy = [
        Algo::Sync,
        Algo::PowerSgd,
        Algo::Local,
        Algo::Overlap,
        Algo::OverlapM,
        Algo::Easgd,
        Algo::Eamsgd,
        Algo::Cocod,
    ];
    for straggler in [StragglerModel::None, StragglerModel::UniformJitter { jitter: 0.2 }] {
        for algo in legacy {
            let mut cfg = golden_cfg(&straggler);
            cfg.algo = algo;
            let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
            let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

            let engine_log = run_experiment(&rt, &cfg, &train, &test).unwrap();
            let ctx = make_ctx(&rt, &cfg, &train, &test);
            let ref_log = reference_log(&ctx);

            assert_eq!(
                engine_log.digest(),
                ref_log.digest(),
                "{algo:?} ({straggler:?}): engine drifted from the legacy loop\n\
                 engine: steps={} bytes={} sim={} comm={} idle={}\n\
                 legacy: steps={} bytes={} sim={} comm={} idle={}",
                engine_log.steps,
                engine_log.bytes_sent,
                engine_log.total_sim_time,
                engine_log.total_comm_blocked_s,
                engine_log.total_idle_s,
                ref_log.steps,
                ref_log.bytes_sent,
                ref_log.total_sim_time,
                ref_log.total_comm_blocked_s,
                ref_log.total_idle_s,
            );
        }
    }
}

#[test]
fn overlap_ada_with_inert_controller_matches_overlap_m_observables() {
    // With an effectively-infinite patience the adaptive controller never
    // fires, so overlap-ada must produce overlap-m's exact observables
    // (modulo the algo name and the τ-trace bookkeeping entry).
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let mut cfg = golden_cfg(&StragglerModel::None);
    cfg.algo = Algo::OverlapAda;
    cfg.ada_patience = usize::MAX;
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let ada = run_experiment(&rt, &cfg, &train, &test).unwrap();

    let mut cfg_m = cfg.clone();
    cfg_m.algo = Algo::OverlapM;
    let m = run_experiment(&rt, &cfg_m, &train, &test).unwrap();

    assert_eq!(ada.steps, m.steps);
    assert_eq!(ada.bytes_sent, m.bytes_sent);
    assert_eq!(ada.total_sim_time.to_bits(), m.total_sim_time.to_bits());
    assert_eq!(ada.total_compute_s.to_bits(), m.total_compute_s.to_bits());
    assert_eq!(
        ada.total_comm_blocked_s.to_bits(),
        m.total_comm_blocked_s.to_bits()
    );
    assert_eq!(ada.step_losses.len(), m.step_losses.len());
    for (a, b) in ada.step_losses.iter().zip(&m.step_losses) {
        assert_eq!(a.0, b.0);
        assert_eq!(a.1.to_bits(), b.1.to_bits());
    }
    for (a, b) in ada.records.iter().zip(&m.records) {
        assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
    }
    assert_eq!(ada.tau_trace, vec![(0, cfg.tau)], "inert controller records only τ0");
    assert!(m.tau_trace.is_empty());
}

#[test]
fn explicit_ring_topology_is_digest_identical_to_the_legacy_loops() {
    // `--topology ring` must be the seed's exact path, not merely a similar
    // one: same chunked schedule, same α/β cost, same byte convention, and
    // an inert neighbor-bytes vector (which stays out of the digest).
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    for algo in [Algo::Sync, Algo::Local, Algo::OverlapM, Algo::Cocod, Algo::Eamsgd] {
        let mut cfg = golden_cfg(&StragglerModel::UniformJitter { jitter: 0.2 });
        cfg.algo = algo;
        cfg.topology = "ring".into();
        let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
        let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
        let engine_log = run_experiment(&rt, &cfg, &train, &test).unwrap();
        assert!(engine_log.neighbor_bytes.iter().all(|&b| b == 0));
        let ctx = make_ctx(&rt, &cfg, &train, &test);
        let ref_log = reference_log(&ctx);
        assert_eq!(
            engine_log.digest(),
            ref_log.digest(),
            "{algo:?}: explicit ring topology drifted from the legacy loop"
        );
    }
}

/// Fixed-seed digest locks for the new axis: every topology (and the
/// decentralized algorithm) must be a pure function of its config — two
/// fresh runs agree bit-for-bit — and the axis must actually bite (each
/// topology lands on a distinct digest, all distinct from the ring).
#[test]
fn new_axis_digests_are_stable_and_distinct() {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let legs: [(&str, Algo); 6] = [
        ("ring", Algo::Local),
        ("hier", Algo::Local),
        ("tree", Algo::Local),
        ("hier", Algo::OverlapM),
        ("tree", Algo::OverlapM),
        ("ring", Algo::OverlapGossip),
    ];
    let mut digests = Vec::new();
    for (topology, algo) in legs {
        let mut cfg = golden_cfg(&StragglerModel::None);
        cfg.workers = 4;
        cfg.train_n = 256; // keep 64/shard with the extra worker
        cfg.algo = algo;
        cfg.topology = topology.into();
        cfg.hier_groups = 2;
        cfg.gossip_degree = 2;
        let run_digest = || {
            let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
            let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
            run_experiment(&rt, &cfg, &train, &test).unwrap().digest()
        };
        let (a, b) = (run_digest(), run_digest());
        assert_eq!(a, b, "{algo:?} on {topology}: digest not reproducible");
        digests.push((topology, algo, a));
    }
    for i in 0..digests.len() {
        for j in i + 1..digests.len() {
            assert_ne!(
                digests[i].2, digests[j].2,
                "{:?} vs {:?}: the topology axis must be digest-visible",
                digests[i], digests[j]
            );
        }
    }
}

/// Cross-backend golden lock (ISSUE 3): on the paper_16node cluster shape
/// (m = 16, the paper's 40 Gbps ring and 188 ms steps) every algorithm
/// must produce the *same* `TrainLog` digest under `--execution threads`
/// as under `sim` — real worker threads, real background communicator
/// threads, zero drift in any observable. Jitter stragglers are on, so the
/// per-worker RNG streams are exercised under true concurrency.
#[test]
fn threads_execution_is_digest_identical_to_sim_for_all_ten_algorithms() {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    for algo in Algo::all() {
        let mut cfg = golden_cfg(&StragglerModel::UniformJitter { jitter: 0.2 });
        cfg.workers = 16; // paper_16node cluster size
        cfg.train_n = 16 * 64; // keep 64/shard -> 2 steps/epoch -> 4 steps
        cfg.algo = *algo;
        let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
        let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

        assert_eq!(cfg.execution, Execution::Sim);
        let sim = run_experiment(&rt, &cfg, &train, &test).unwrap();
        cfg.execution = Execution::Threads;
        let thr = run_experiment(&rt, &cfg, &train, &test).unwrap();

        assert_eq!(
            sim.digest(),
            thr.digest(),
            "{algo:?}: threads backend drifted from sim\n\
             sim:     steps={} bytes={} sim_time={} comm={} idle={}\n\
             threads: steps={} bytes={} sim_time={} comm={} idle={}",
            sim.steps,
            sim.bytes_sent,
            sim.total_sim_time,
            sim.total_comm_blocked_s,
            sim.total_idle_s,
            thr.steps,
            thr.bytes_sent,
            thr.total_sim_time,
            thr.total_comm_blocked_s,
            thr.total_idle_s,
        );
    }
}

/// The same cross-backend lock on the non-ring topologies (every exact
/// graph plus the gossip axis): the executor must not interact with the
/// topology subsystem's data or timing planes.
#[test]
fn threads_execution_is_digest_identical_to_sim_across_topologies() {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let legs: [(&str, Algo); 7] = [
        ("hier", Algo::Local),
        ("hier", Algo::OverlapM),
        ("hier", Algo::Cocod),
        ("tree", Algo::Local),
        ("tree", Algo::OverlapM),
        ("tree", Algo::Sync),
        ("gossip", Algo::OverlapGossip),
    ];
    for (topology, algo) in legs {
        let mut cfg = golden_cfg(&StragglerModel::ShiftedExp { scale: 0.3 });
        cfg.workers = 4;
        cfg.train_n = 256;
        cfg.algo = algo;
        cfg.topology = topology.into();
        cfg.hier_groups = 2;
        cfg.gossip_degree = 2;
        let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
        let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

        let sim = run_experiment(&rt, &cfg, &train, &test).unwrap();
        cfg.execution = Execution::Threads;
        let thr = run_experiment(&rt, &cfg, &train, &test).unwrap();
        assert_eq!(
            sim.digest(),
            thr.digest(),
            "{algo:?} on {topology}: threads backend drifted from sim"
        );
    }
}

#[test]
fn golden_digests_are_reproducible_across_processes_inputs() {
    // The digest must not depend on incidental state (allocation, ordering
    // of independent runs): interleave two configs and re-run.
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let mut first = Vec::new();
    let mut second = Vec::new();
    for pass in 0..2 {
        for algo in [Algo::Sync, Algo::OverlapM, Algo::Cocod] {
            let mut cfg = golden_cfg(&StragglerModel::ShiftedExp { scale: 0.3 });
            cfg.algo = algo;
            let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
            let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
            let d = run_experiment(&rt, &cfg, &train, &test).unwrap().digest();
            if pass == 0 {
                first.push(d);
            } else {
                second.push(d);
            }
        }
    }
    assert_eq!(first, second, "digests must be a pure function of the config");
    assert_ne!(first[0], first[1], "different algorithms must not collide");
}
