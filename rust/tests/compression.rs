//! E2E coverage for the composable compression axis (`--compress`,
//! DESIGN.md §12): every compressor composes with the fault model and both
//! execution backends; `--algo powersgd` is exactly `--algo sync --compress
//! powersgd`; lossless-limit settings track the uncompressed run; and the
//! compressed wire sizes flow through `bytes_sent` / `neighbor_bytes`.
//!
//! The headline regression here is `powersgd_survives_crash_and_rejoin`:
//! before the compression seam, `--algo powersgd --fault crash@...` was a
//! hard refusal ("powersgd does not support fault injection"). Per-worker
//! error-feedback residuals are now first-class engine state with a rejoin
//! protocol, so the exact schedule that used to error must run, agree across
//! backends bit-for-bit, and replay deterministically.

use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;

/// The m = 16 paper cluster shape used by the E14 fault suite: 4 rounds at
/// τ = 2 with jitter stragglers, so the per-worker RNG streams are live.
fn paper16(algo: Algo) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 16;
    cfg.train_n = 16 * 64; // 64/shard -> 2 steps/epoch
    cfg.test_n = 100;
    cfg.epochs = 4.0; // 8 global steps -> 4 rounds at tau = 2
    cfg.eval_every = 2.0;
    cfg.tau = 2;
    cfg.algo = algo;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg
}

/// Run one config on the sim backend.
fn native_run(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

/// Run one config on both execution backends.
fn run_both(cfg: &ExperimentConfig) -> (TrainLog, TrainLog) {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let mut sim_cfg = cfg.clone();
    sim_cfg.execution = Execution::Sim;
    let sim = run_experiment(&rt, &sim_cfg, &train, &test).unwrap();
    let mut thr_cfg = cfg.clone();
    thr_cfg.execution = Execution::Threads;
    let thr = run_experiment(&rt, &thr_cfg, &train, &test).unwrap();
    (sim, thr)
}

/// The deleted-refusal regression: this exact schedule used to error with
/// "--algo powersgd does not support fault injection". Now the compressor's
/// per-worker residuals and warm-start basis crash and rejoin cleanly.
#[test]
fn powersgd_survives_crash_and_rejoin() {
    let mut cfg = paper16(Algo::PowerSgd);
    cfg.set("fault", "crash@2:1;rejoin@4:1").unwrap();
    let (sim, thr) = run_both(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "powersgd fault run drifted across backends");
    assert_eq!(
        sim.fault_trace,
        vec![(2, "crash@2:1".to_string()), (4, "rejoin@4:1".to_string())]
    );
    assert_eq!(sim.survivors, vec![(2, 15), (4, 16)]);
    assert!(sim.final_loss().is_finite());
    // Deterministic replay: an identical pair reproduces the digest.
    let (sim2, _) = run_both(&cfg);
    assert_eq!(sim.digest(), sim2.digest(), "powersgd fault replay must be pure");
}

/// Every compressor composes with a crash schedule on the overlapped path
/// (`--compress topk --fault crash@...` end-to-end), with sim ↔ threads
/// digest equality — the acceptance-criterion composition.
#[test]
fn every_compressor_composes_with_crash_faults() {
    for kind in ["topk", "qsgd", "powersgd"] {
        let mut cfg = paper16(Algo::OverlapM);
        cfg.set("compress", kind).unwrap();
        cfg.set("fault", "crash@3:2").unwrap();
        let (sim, thr) = run_both(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{kind}: crash run drifted across backends");
        assert_eq!(sim.survivors, vec![(3, 15)], "{kind}");
        assert!(sim.final_loss().is_finite(), "{kind}");
    }
    // The decentralized path too: sparse gossip over the live edges.
    let mut cfg = paper16(Algo::OverlapGossip);
    cfg.set("compress", "topk").unwrap();
    cfg.set("fault", "crash@3:2").unwrap();
    let (sim, thr) = run_both(&cfg);
    assert_eq!(sim.digest(), thr.digest(), "gossip+topk crash run drifted");
    assert!(sim.final_loss().is_finite());
}

/// Compressed error-feedback state survives a partition + heal for every
/// compressor: the minority parks, residuals mask to the survivor set
/// (exactly mean-preserving — unit-level proof in compress/state.rs), and
/// the healed run stays backend-invariant.
#[test]
fn every_compressor_survives_partition_and_heal() {
    for kind in ["topk", "qsgd", "powersgd"] {
        let mut cfg = paper16(Algo::OverlapM);
        cfg.set("compress", kind).unwrap();
        cfg.set(
            "fault",
            "partition@2:0,1,2,3,4,5,6|7,8,9,10,11,12,13,14,15;heal@4",
        )
        .unwrap();
        let (sim, thr) = run_both(&cfg);
        assert_eq!(sim.digest(), thr.digest(), "{kind}: partition run drifted");
        assert_eq!(sim.survivors, vec![(2, 9), (4, 16)], "{kind}");
        assert!(sim.final_loss().is_finite(), "{kind}");
    }
}

/// `--algo powersgd` is exactly `--algo sync --compress powersgd`: identical
/// trajectories, bytes, and timeline. Only the algorithm *label* differs
/// (it names what the user asked for), so the digests — which include the
/// label — differ while every measured field agrees bit-for-bit.
#[test]
fn algo_powersgd_is_sync_under_compress_powersgd() {
    let a = native_run(&paper16(Algo::PowerSgd));
    let mut cfg = paper16(Algo::Sync);
    cfg.set("compress", "powersgd").unwrap();
    let b = native_run(&cfg);

    assert_eq!(a.algo, "powersgd");
    assert_eq!(a.compress, "powersgd", "the alias must report its compressor");
    assert_eq!(b.algo, "sync");
    assert_eq!(b.compress, "powersgd");

    assert_eq!(a.step_losses, b.step_losses, "trajectories must be identical");
    assert_eq!(a.bytes_sent, b.bytes_sent, "wire accounting must be identical");
    assert_eq!(a.total_sim_time.to_bits(), b.total_sim_time.to_bits());
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.test_loss.to_bits(), rb.test_loss.to_bits());
        assert_eq!(ra.test_acc.to_bits(), rb.test_acc.to_bits());
        assert_eq!(ra.sim_time.to_bits(), rb.sim_time.to_bits());
    }
    assert_ne!(a.digest(), b.digest(), "the algo label is digest-visible by design");
}

/// Compressed payload sizes flow through the byte accounting: every real
/// compressor sends strictly fewer bytes than `--compress none` on the same
/// run, and on the hierarchical topology the per-worker `neighbor_bytes`
/// split shrinks with them.
#[test]
fn compressed_runs_send_fewer_bytes() {
    let base = native_run(&paper16(Algo::Sync));
    assert_eq!(base.compress, "none");
    for kind in ["topk", "qsgd", "powersgd"] {
        let mut cfg = paper16(Algo::Sync);
        cfg.set("compress", kind).unwrap();
        let log = native_run(&cfg);
        assert!(
            log.bytes_sent < base.bytes_sent,
            "{kind}: compressed bytes {} must undercut uncompressed {}",
            log.bytes_sent,
            base.bytes_sent
        );
    }
    // Per-topology cost formulas see the scaled size too.
    let mut hier = paper16(Algo::Sync);
    hier.set("topology", "hier").unwrap();
    let hier_base = native_run(&hier);
    let mut hier_topk = hier.clone();
    hier_topk.set("compress", "topk").unwrap();
    let hier_log = native_run(&hier_topk);
    let sum = |l: &TrainLog| l.neighbor_bytes.iter().sum::<u64>();
    assert!(sum(&hier_base) > 0, "hier must report a per-worker byte split");
    assert!(
        sum(&hier_log) < sum(&hier_base),
        "hier neighbor_bytes must shrink under topk: {:?} vs {:?}",
        hier_log.neighbor_bytes,
        hier_base.neighbor_bytes
    );
    assert!(hier_log.total_sim_time < hier_base.total_sim_time,
        "a smaller wire payload must shorten the blocking exchange");
}

/// Lossless limits: top-k at k = d and QSGD at 32 bits reproduce the
/// uncompressed trajectory up to f32 summation order (the compressed mean
/// accumulates per-element; the exact collective reduces in topology order),
/// and full-bits QSGD charges exactly the uncompressed wire size.
#[test]
fn lossless_limits_track_the_uncompressed_run() {
    let close = |x: f64, y: f64| (x - y).abs() <= 1e-3 * (1.0 + x.abs().max(y.abs()));
    let base = native_run(&paper16(Algo::Sync));

    let mut topk = paper16(Algo::Sync);
    topk.set("compress", "topk").unwrap();
    topk.set("compress_k", "100000000").unwrap(); // clamps to d: identity mask
    let t = native_run(&topk);

    let mut qsgd = paper16(Algo::Sync);
    qsgd.set("compress", "qsgd").unwrap();
    qsgd.set("compress_bits", "32").unwrap(); // bitwise passthrough encode
    let q = native_run(&qsgd);

    for log in [&t, &q] {
        assert_eq!(log.step_losses.len(), base.step_losses.len());
        for ((ka, la), (kb, lb)) in log.step_losses.iter().zip(&base.step_losses) {
            assert_eq!(ka, kb);
            assert!(close(*la, *lb), "lossless-limit loss drifted: {la} vs {lb} at step {ka}");
        }
        assert!(close(log.final_loss(), base.final_loss()));
    }
    // 32-bit QSGD is a frac = 1.0 wire plan: byte-identical accounting.
    assert_eq!(q.bytes_sent, base.bytes_sent, "full-bits qsgd must charge full bytes");
}

/// The compressor label is reported (JSON + struct field) on every run but
/// stays out of the digest — `--compress none` runs hash identically to the
/// pre-seam binary (unit-level assertion in metrics; here: the field is
/// present and truthful end-to-end).
#[test]
fn compress_label_is_reported_end_to_end() {
    let base = native_run(&paper16(Algo::OverlapM));
    assert_eq!(base.compress, "none");
    let mut cfg = paper16(Algo::OverlapM);
    cfg.set("compress", "qsgd").unwrap();
    cfg.set("compress_bits", "4").unwrap();
    let log = native_run(&cfg);
    assert_eq!(log.compress, "qsgd");
    let json = log.to_json().to_string_pretty();
    assert!(json.contains("\"compress\""), "compress label missing from JSON: {json}");
    assert!(log.final_loss().is_finite());
}
