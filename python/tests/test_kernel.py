"""Layer-1 correctness: every Pallas kernel vs the pure-jnp oracle.

Fixed-shape checks here; hypothesis shape/seed sweeps in
``test_kernel_hypothesis.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import fused_update, matmul, ref

KEY = jax.random.PRNGKey(0)


def _randn(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


# --------------------------------------------------------------------------
# matmul_bias
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 8, 8),          # single block
        (32, 3072, 128),    # mlp fc1 shape
        (32, 64, 10),       # ragged N (pad + slice)
        (100, 32, 10),      # ragged M (eval batch)
        (1, 7, 3),          # degenerate tiny
        (256, 256, 256),    # multi-block all dims
        (129, 130, 131),    # all dims ragged
    ],
)
@pytest.mark.parametrize("fuse_relu", [False, True])
def test_matmul_bias_matches_ref(m, k, n, fuse_relu):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x, w, b = _randn(k1, m, k), _randn(k2, k, n), _randn(k3, n)
    got = matmul.matmul_bias(x, w, b, fuse_relu=fuse_relu)
    want = ref.matmul_bias(x, w, b, fuse_relu=fuse_relu)
    assert got.shape == (m, n)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_matmul_zero_padding_is_exact():
    # Padding must not leak: compare a ragged case against explicit slicing
    # of an embedded multiple-of-block computation.
    k1, k2 = jax.random.split(KEY)
    x, w = _randn(k1, 17, 23), _randn(k2, 23, 9)
    b = jnp.zeros(9)
    got = matmul.matmul_bias(x, w, b)
    assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# fused elementwise kernels
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 100, 8192, 8193, 50_000])
def test_nesterov_update_matches_ref(n):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x, v, g = _randn(k1, n), _randn(k2, n), _randn(k3, n)
    lr, mu, wd = jnp.array([0.1]), jnp.array([0.9]), jnp.array([1e-4])
    gx, gv = fused_update.nesterov_update(x, v, g, lr, mu, wd)
    wx, wv = ref.nesterov_update(x, v, g, lr, mu, wd)
    assert_allclose(np.asarray(gx), np.asarray(wx), rtol=1e-6, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6, atol=1e-6)


def test_nesterov_mu_zero_is_plain_sgd():
    """mu = 0, wd = 0 must reduce to x - lr * g (the vanilla-variant path)."""
    k1, k2 = jax.random.split(KEY)
    x, g = _randn(k1, 1000), _randn(k2, 1000)
    v = jnp.zeros(1000)
    lr = jnp.array([0.05])
    gx, gv = fused_update.nesterov_update(x, v, g, lr, jnp.array([0.0]), jnp.array([0.0]))
    assert_allclose(np.asarray(gx), np.asarray(x - 0.05 * g), rtol=1e-6, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(g), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [1, 8192, 10_001])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 0.6, 1.0])
def test_pullback_matches_ref(n, alpha):
    k1, k2 = jax.random.split(KEY)
    x, z = _randn(k1, n), _randn(k2, n)
    a = jnp.array([alpha])
    got = fused_update.pullback(x, z, a)
    assert_allclose(np.asarray(got), np.asarray(ref.pullback(x, z, a)),
                    rtol=1e-6, atol=1e-6)


def test_pullback_endpoints():
    """alpha=0 is identity; alpha=1 lands exactly on the anchor (Eq. 4)."""
    k1, k2 = jax.random.split(KEY)
    x, z = _randn(k1, 512), _randn(k2, 512)
    assert_allclose(np.asarray(fused_update.pullback(x, z, jnp.array([0.0]))),
                    np.asarray(x), rtol=0, atol=0)
    assert_allclose(np.asarray(fused_update.pullback(x, z, jnp.array([1.0]))),
                    np.asarray(z), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [1, 8192, 12_345])
@pytest.mark.parametrize("beta", [0.0, 0.7])
def test_anchor_update_matches_ref(n, beta):
    k1, k2, k3 = jax.random.split(KEY, 3)
    z, v, avg = _randn(k1, n), _randn(k2, n), _randn(k3, n)
    b = jnp.array([beta])
    gz, gv = fused_update.anchor_update(z, v, avg, b)
    wz, wv = ref.anchor_update(z, v, avg, b)
    assert_allclose(np.asarray(gz), np.asarray(wz), rtol=1e-6, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6, atol=1e-6)


def test_anchor_beta_zero_is_vanilla_assignment():
    """beta = 0 reduces Eqs. (10)-(11) to the vanilla anchor z' = avg (Eq. 5)."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    z, v, avg = _randn(k1, 777), _randn(k2, 777), _randn(k3, 777)
    gz, gv = fused_update.anchor_update(z, v, avg, jnp.array([0.0]))
    assert_allclose(np.asarray(gz), np.asarray(avg), rtol=1e-6, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(avg - z), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [1, 8192, 40_000])
@pytest.mark.parametrize("t", [1.0, 7.0, 500.0])
def test_adam_update_matches_ref(n, t):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x, m, v, g = _randn(k1, n), _randn(k2, n), jnp.abs(_randn(k3, n)), _randn(k4, n)
    lr, tt = jnp.array([1e-3]), jnp.array([t])
    gx, gm, gv = fused_update.adam_update(x, m, v, g, lr, tt, wd=1e-2)
    wx, wm, wv = ref.adam_update(x, m, v, g, lr, tt, wd=1e-2)
    assert_allclose(np.asarray(gx), np.asarray(wx), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(gm), np.asarray(wm), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-5, atol=1e-6)


def test_adam_first_step_is_signlike():
    """At t=1 with m=v=0, Adam's update direction is ~sign(g) * lr."""
    k1, k2 = jax.random.split(KEY)
    x, g = _randn(k1, 2000), _randn(k2, 2000)
    zeros = jnp.zeros(2000)
    gx, _, _ = fused_update.adam_update(x, zeros, zeros, g, jnp.array([1e-3]),
                                        jnp.array([1.0]))
    step = np.asarray(x - gx)
    assert np.all(np.sign(step[np.abs(step) > 1e-6])
                  == np.sign(np.asarray(g)[np.abs(step) > 1e-6]))
    assert np.max(np.abs(step)) <= 1e-3 + 1e-6
