"""Layer-2 correctness: models, layouts, gradients, train/eval semantics."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.kernels import ref

KEY = jax.random.PRNGKey(42)


def _init_flat(layout, key):
    """He-normal init identical in spirit to the Rust-side initializer."""
    flat = np.zeros(layout.total, dtype=np.float32)
    for s in layout.specs:
        if s.init == "he_normal":
            key, sub = jax.random.split(key)
            std = math.sqrt(2.0 / s.fan_in)
            vals = std * jax.random.normal(sub, (s.size,), dtype=jnp.float32)
            flat[s.offset : s.offset + s.size] = np.asarray(vals)
    return jnp.asarray(flat)


def _batch(key, b=8):
    k1, k2 = jax.random.split(key)
    imgs = jax.random.normal(k1, (b, *M.IMAGE_SHAPE), dtype=jnp.float32)
    labels = jax.random.randint(k2, (b,), 0, M.NUM_CLASSES)
    return imgs, labels


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["mlp", "cnn", "cnn_wide"])
def test_layout_is_contiguous_and_complete(model):
    layout = M.MODELS[model][0]()
    off = 0
    for s in layout.specs:
        assert s.offset == off, f"{s.name} offset gap"
        off += s.size
    assert off == layout.total


@pytest.mark.parametrize("model", ["mlp", "cnn", "cnn_wide"])
def test_unpack_round_trip(model):
    layout = M.MODELS[model][0]()
    flat = jnp.arange(layout.total, dtype=jnp.float32)
    params = layout.unpack(flat)
    for s in layout.specs:
        expect = jnp.arange(s.offset, s.offset + s.size, dtype=jnp.float32).reshape(s.shape)
        assert_allclose(np.asarray(params[s.name]), np.asarray(expect))


def test_manifest_matrix_shapes():
    layout = M.cnn_layout("cnn")
    entries = {e["name"]: e for e in layout.manifest()}
    assert entries["conv1.w"]["rows"] == 27 and entries["conv1.w"]["cols"] == 8
    assert entries["conv1.w"]["compress"]
    assert entries["conv1.b"]["rows"] == 1 and not entries["conv1.b"]["compress"]
    assert entries["fc1.w"]["rows"] == 8 * 8 * 32


# --------------------------------------------------------------------------
# Gradients: the Pallas-backed model must differentiate like the jnp oracle
# --------------------------------------------------------------------------


def _ref_forward(model, layout, flat, images):
    """Forward pass with every Pallas matmul swapped for the jnp oracle."""
    params = layout.unpack(flat)
    if model == "mlp":
        x = images.reshape(images.shape[0], -1)
        x = ref.matmul_bias(x, params["fc1.w"], params["fc1.b"], fuse_relu=True)
        x = ref.matmul_bias(x, params["fc2.w"], params["fc2.b"], fuse_relu=True)
        return ref.matmul_bias(x, params["fc3.w"], params["fc3.b"])
    x = M._conv2d(images, params["conv1.w"], params["conv1.b"], 1)
    x = M._conv2d(x, params["conv2.w"], params["conv2.b"], 2)
    x = M._conv2d(x, params["conv3.w"], params["conv3.b"], 2)
    x = x.reshape(x.shape[0], -1)
    x = ref.matmul_bias(x, params["fc1.w"], params["fc1.b"], fuse_relu=True)
    return ref.matmul_bias(x, params["fc2.w"], params["fc2.b"])


@pytest.mark.parametrize("model", ["mlp", "cnn"])
def test_gradients_match_jnp_oracle(model):
    layout, _, grad_step, _ = M.make_functions(model)
    flat = _init_flat(layout, KEY)
    imgs, labels = _batch(jax.random.PRNGKey(7))

    def ref_loss(f):
        logits = _ref_forward(model, layout, f, imgs)
        return jnp.mean(M._xent(logits, labels))

    loss, g = grad_step(flat, imgs, labels)
    ref_l, ref_g = jax.value_and_grad(ref_loss)(flat)
    assert_allclose(float(loss), float(ref_l), rtol=1e-4)
    assert_allclose(np.asarray(g), np.asarray(ref_g), rtol=1e-3, atol=1e-5)


# --------------------------------------------------------------------------
# Training semantics
# --------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["mlp", "cnn"])
def test_train_step_decreases_loss(model):
    layout, train_step, _, _ = M.make_functions(model)
    flat = _init_flat(layout, KEY)
    mom = jnp.zeros(layout.total)
    imgs, labels = _batch(jax.random.PRNGKey(3), b=16)
    lr, mu, wd = jnp.array([0.05]), jnp.array([0.9]), jnp.array([0.0])

    losses = []
    for _ in range(12):
        flat, mom, loss = train_step(flat, mom, imgs, labels, lr, mu, wd)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, f"no progress: {losses[:3]} .. {losses[-3:]}"


def test_train_step_momentum_buffer_updates():
    layout, train_step, _, _ = M.make_functions("mlp")
    flat = _init_flat(layout, KEY)
    mom = jnp.zeros(layout.total)
    imgs, labels = _batch(jax.random.PRNGKey(5))
    _, mom2, _ = train_step(flat, mom, imgs, labels,
                            jnp.array([0.1]), jnp.array([0.9]), jnp.array([0.0]))
    assert float(jnp.linalg.norm(mom2)) > 0.0


def test_evaluate_counts_match_numpy_argmax():
    layout, _, _, evaluate = M.make_functions("mlp")
    flat = _init_flat(layout, KEY)
    imgs, labels = _batch(jax.random.PRNGKey(11), b=32)
    sum_loss, correct = evaluate(flat, imgs, labels)

    params = layout.unpack(flat)
    logits = np.asarray(_ref_forward("mlp", layout, flat, imgs))
    want = int(np.sum(np.argmax(logits, axis=1) == np.asarray(labels)))
    assert int(correct) == want
    assert float(sum_loss) > 0.0


def test_grad_step_and_train_step_agree():
    """train_step == grad_step + fused nesterov, by construction."""
    layout, train_step, grad_step, _ = M.make_functions("mlp")
    flat = _init_flat(layout, KEY)
    mom = jnp.zeros(layout.total)
    imgs, labels = _batch(jax.random.PRNGKey(13))
    lr, mu, wd = jnp.array([0.1]), jnp.array([0.9]), jnp.array([1e-4])

    f1, m1, l1 = train_step(flat, mom, imgs, labels, lr, mu, wd)
    l2, g = grad_step(flat, imgs, labels)
    f2, m2 = ref.nesterov_update(flat, mom, g, lr, mu, wd)
    assert_allclose(float(l1), float(l2), rtol=1e-5)
    assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4, atol=1e-6)
    assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-4, atol=1e-6)
