"""Hypothesis sweeps over the Layer-1 kernels' shape/value space.

Each property run re-derives the kernel output against the pure-jnp oracle
for randomly drawn shapes, seeds, and hyper-parameters — the broad-coverage
complement to the fixed-shape checks in test_kernel.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import fused_update, matmul, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _randn(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 160),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
    fuse_relu=st.booleans(),
)
def test_matmul_bias_property(m, k, n, seed, fuse_relu):
    x = _randn(seed, m, k)
    w = _randn(seed + 1, k, n)
    b = _randn(seed + 2, n)
    got = matmul.matmul_bias(x, w, b, fuse_relu=fuse_relu)
    want = ref.matmul_bias(x, w, b, fuse_relu=fuse_relu)
    assert got.shape == (m, n)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 40_000),
    seed=st.integers(0, 2**31 - 1),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    wd=st.floats(0.0, 1e-2),
)
def test_nesterov_property(n, seed, lr, mu, wd):
    x, v, g = _randn(seed, n), _randn(seed + 1, n), _randn(seed + 2, n)
    args = (jnp.array([lr], jnp.float32), jnp.array([mu], jnp.float32),
            jnp.array([wd], jnp.float32))
    gx, gv = fused_update.nesterov_update(x, v, g, *args)
    wx, wv = ref.nesterov_update(x, v, g, *args)
    assert_allclose(np.asarray(gx), np.asarray(wx), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(n=st.integers(1, 40_000), seed=st.integers(0, 2**31 - 1),
       alpha=st.floats(0.0, 1.0))
def test_pullback_property(n, seed, alpha):
    x, z = _randn(seed, n), _randn(seed + 1, n)
    a = jnp.array([alpha], jnp.float32)
    got = fused_update.pullback(x, z, a)
    assert_allclose(np.asarray(got), np.asarray(ref.pullback(x, z, a)),
                    rtol=1e-5, atol=1e-6)
    # Pullback is a convex combination: result lies between x and z.
    lo = np.minimum(np.asarray(x), np.asarray(z)) - 1e-6
    hi = np.maximum(np.asarray(x), np.asarray(z)) + 1e-6
    gotn = np.asarray(got)
    assert np.all(gotn >= lo) and np.all(gotn <= hi)


@settings(**SETTINGS)
@given(n=st.integers(1, 40_000), seed=st.integers(0, 2**31 - 1),
       beta=st.floats(0.0, 0.99))
def test_anchor_property(n, seed, beta):
    z, v, avg = _randn(seed, n), _randn(seed + 1, n), _randn(seed + 2, n)
    b = jnp.array([beta], jnp.float32)
    gz, gv = fused_update.anchor_update(z, v, avg, b)
    wz, wv = ref.anchor_update(z, v, avg, b)
    assert_allclose(np.asarray(gz), np.asarray(wz), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-5, atol=1e-6)
    # Invariant: z' - z == v' exactly (Eq. 11).
    assert_allclose(np.asarray(gz - z), np.asarray(gv), rtol=1e-5, atol=1e-6)
