"""AOT entrypoint: lower every Layer-2 computation to HLO text + manifest.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --outdir ../artifacts

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts written (per model M in --models):

    artifacts/train_step_M.hlo.txt   (flat, mom, x, y, lr, mu, wd) -> (flat', mom', loss)
    artifacts/grad_step_M.hlo.txt    (flat, x, y)                  -> (loss, grads)
    artifacts/eval_M.hlo.txt         (flat, x, y)                  -> (sum_loss, correct)
    artifacts/pullback_M.hlo.txt     (x, z, alpha)                 -> (x',)
    artifacts/anchor_M.hlo.txt       (z, v, avg, beta)             -> (z', v')
    artifacts/manifest.json          layouts, shapes, module table
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_model(name: str, outdir: str, train_batch: int, eval_batch: int) -> dict:
    layout, train_step, grad_step, evaluate = M.make_functions(name)
    n = layout.total
    h, w, c = M.IMAGE_SHAPE

    vec = _spec(n)
    scalar = _spec(1)
    timgs, tlabels = _spec(train_batch, h, w, c), _spec(train_batch, dtype=jnp.int32)
    eimgs, elabels = _spec(eval_batch, h, w, c), _spec(eval_batch, dtype=jnp.int32)

    modules = {}

    def emit(tag, fn, *args):
        path = f"{tag}_{name}.hlo.txt"
        text = to_hlo_text(jax.jit(fn).lower(*args))
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        modules[tag] = path
        print(f"  wrote {path} ({len(text)} chars)")

    emit("train_step", train_step, vec, vec, timgs, tlabels, scalar, scalar, scalar)
    emit("grad_step", grad_step, vec, timgs, tlabels)
    emit("eval", evaluate, vec, eimgs, elabels)
    emit("pullback", lambda x, z, a: (M.pullback(x, z, a),), vec, vec, scalar)
    emit("anchor", M.anchor_update, vec, vec, vec, scalar)
    # Standalone fused Nesterov/SGD step — applies an externally averaged
    # gradient (sync-SGD / PowerSGD paths) through the same Pallas kernel.
    emit("update", M.sgd_update, vec, vec, vec, scalar, scalar, scalar)
    # Fused Adam — the paper's §6 extension (Overlap-Local-Adam).
    emit("adam", M.adam_update, vec, vec, vec, vec, scalar, scalar)

    return {
        "param_count": n,
        "tensors": layout.manifest(),
        "modules": modules,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--models", default="mlp,cnn,cnn_wide")
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=100)
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    manifest = {
        "image_shape": list(M.IMAGE_SHAPE),
        "num_classes": M.NUM_CLASSES,
        "train_batch": args.train_batch,
        "eval_batch": args.eval_batch,
        "models": {},
    }
    for name in args.models.split(","):
        name = name.strip()
        print(f"lowering model '{name}' ...")
        manifest["models"][name] = lower_model(
            name, args.outdir, args.train_batch, args.eval_batch
        )

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
