"""Layer-2: JAX models and the exported train/eval computations.

Everything here is build-time Python. ``aot.py`` lowers the jitted functions
below to HLO text once; the Rust coordinator then executes the artifacts via
PJRT with **no Python on the request path**.

Parameters travel as ONE flat f32 vector so the Rust side is shape-oblivious
(flat-vector averaging/pullback is exactly how the paper's algorithms are
stated). The static layout — (name, shape, offset, init) per tensor — is
emitted into ``artifacts/manifest.json`` so Rust can (a) initialize params
with its own PRNG and (b) re-matricize gradients for PowerSGD.

Models
------
* ``mlp``      3072 -> 128 -> 64 -> 10 dense net; dense layers run on the
               Layer-1 Pallas matmul kernel. (~0.40 M params)
* ``cnn``      CIFAR-style conv net: 3 conv3x3 blocks (8, 16, 32 ch, stride-2
               downsampling) + GAP + Pallas dense head. (~7 k params) The
               scaled stand-in for the paper's ResNet-18 — see DESIGN.md §3.
* ``cnn_wide`` same topology at 16/32/64 channels + 128-wide head for the
               larger e2e runs. (~38 k params)

Exported computations (per model)
---------------------------------
* ``train_step(flat, mom, images, labels, lr, mu, wd)``
      -> (flat', mom', loss)       fwd+bwd + fused Nesterov (Pallas)
* ``grad_step(flat, images, labels)``
      -> (loss, flat_grads)        raw grads for sync-SGD / PowerSGD
* ``evaluate(flat, images, labels)``
      -> (sum_loss, num_correct)   test-set metrics (count as f32)

plus the model-independent ``pullback`` and ``anchor_update`` vector ops
from the Layer-1 kernels.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from compile.kernels import fused_update, matmul

IMAGE_SHAPE = (32, 32, 3)
NUM_CLASSES = 10


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------


@dataclass
class TensorSpec:
    """One parameter tensor inside the flat vector."""

    name: str
    shape: tuple
    offset: int
    init: str        # "he_normal" (std = sqrt(2 / fan_in)) | "zeros"
    fan_in: int
    compress: bool   # PowerSGD compresses matrices, leaves biases raw

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    def matrix_shape(self) -> tuple:
        """(rows, cols) view used by PowerSGD matricization."""
        if len(self.shape) == 1:
            return (1, self.shape[0])
        if len(self.shape) == 2:
            return self.shape
        # conv kernel (kh, kw, cin, cout) -> (kh*kw*cin, cout)
        rows = int(math.prod(self.shape[:-1]))
        return (rows, self.shape[-1])


@dataclass
class ParamLayout:
    specs: list = field(default_factory=list)
    total: int = 0

    def add(self, name: str, shape: tuple, init: str, fan_in: int, compress: bool):
        self.specs.append(
            TensorSpec(name, tuple(shape), self.total, init, fan_in, compress)
        )
        self.total += int(math.prod(shape))

    def unpack(self, flat: jnp.ndarray) -> dict:
        return {
            s.name: jax.lax.dynamic_slice(flat, (s.offset,), (s.size,)).reshape(s.shape)
            for s in self.specs
        }

    def manifest(self) -> list:
        out = []
        for s in self.specs:
            rows, cols = s.matrix_shape()
            out.append(
                {
                    "name": s.name,
                    "shape": list(s.shape),
                    "offset": s.offset,
                    "size": s.size,
                    "init": s.init,
                    "fan_in": s.fan_in,
                    "std": (math.sqrt(2.0 / s.fan_in) if s.init == "he_normal" else 0.0),
                    "rows": rows,
                    "cols": cols,
                    "compress": s.compress,
                }
            )
        return out


# --------------------------------------------------------------------------
# Model definitions
# --------------------------------------------------------------------------


def _dense(layout: ParamLayout, name: str, din: int, dout: int):
    layout.add(f"{name}.w", (din, dout), "he_normal", din, True)
    layout.add(f"{name}.b", (dout,), "zeros", din, False)


def _conv(layout: ParamLayout, name: str, cin: int, cout: int, k: int = 3):
    layout.add(f"{name}.w", (k, k, cin, cout), "he_normal", k * k * cin, True)
    layout.add(f"{name}.b", (cout,), "zeros", k * k * cin, False)


def mlp_layout() -> ParamLayout:
    lay = ParamLayout()
    din = int(math.prod(IMAGE_SHAPE))
    _dense(lay, "fc1", din, 128)
    _dense(lay, "fc2", 128, 64)
    _dense(lay, "fc3", 64, NUM_CLASSES)
    return lay


def mlp_forward(params: dict, images: jnp.ndarray) -> jnp.ndarray:
    x = images.reshape(images.shape[0], -1)
    x = matmul.matmul_bias(x, params["fc1.w"], params["fc1.b"], fuse_relu=True)
    x = matmul.matmul_bias(x, params["fc2.w"], params["fc2.b"], fuse_relu=True)
    return matmul.matmul_bias(x, params["fc3.w"], params["fc3.b"])


_CNN_CHANNELS = {"cnn": (8, 16, 32, 32), "cnn_wide": (16, 32, 64, 128)}


def cnn_layout(variant: str = "cnn") -> ParamLayout:
    c1, c2, c3, head = _CNN_CHANNELS[variant]
    lay = ParamLayout()
    _conv(lay, "conv1", 3, c1)
    _conv(lay, "conv2", c1, c2)   # stride 2
    _conv(lay, "conv3", c2, c3)   # stride 2
    # flatten 8x8xc3 (spatial information preserved; GAP would discard the
    # per-location pattern the classes differ by)
    _dense(lay, "fc1", 8 * 8 * c3, head)
    _dense(lay, "fc2", head, NUM_CLASSES)
    return lay


def _conv2d(x, w, b, stride: int):
    """conv3x3 + parameter-free instance norm + ReLU.

    The paper's ResNet-18 relies on BatchNorm for stability at lr 0.1; our
    scaled CNN uses an affine-free instance normalization (zero mean / unit
    variance over each sample's spatial extent, per channel) as the
    batch-size-independent stand-in. No learnable parameters — the flat
    param vector stays exactly the conv/dense weights the algorithms mix.
    """
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    mean = jnp.mean(out, axis=(1, 2), keepdims=True)
    var = jnp.var(out, axis=(1, 2), keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 1e-5)
    return jnp.maximum(out + b, 0.0)


def cnn_forward(params: dict, images: jnp.ndarray) -> jnp.ndarray:
    x = _conv2d(images, params["conv1.w"], params["conv1.b"], 1)   # 32x32
    x = _conv2d(x, params["conv2.w"], params["conv2.b"], 2)        # 16x16
    x = _conv2d(x, params["conv3.w"], params["conv3.b"], 2)        # 8x8
    x = x.reshape(x.shape[0], -1)                                  # flatten
    x = matmul.matmul_bias(x, params["fc1.w"], params["fc1.b"], fuse_relu=True)
    return matmul.matmul_bias(x, params["fc2.w"], params["fc2.b"])


MODELS = {
    "mlp": (mlp_layout, mlp_forward),
    "cnn": (lambda: cnn_layout("cnn"), cnn_forward),
    "cnn_wide": (lambda: cnn_layout("cnn_wide"), cnn_forward),
}


# --------------------------------------------------------------------------
# Loss / train / eval computations
# --------------------------------------------------------------------------


def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example softmax cross-entropy, f32[B]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, NUM_CLASSES, dtype=logits.dtype)
    return -jnp.sum(onehot * logp, axis=-1)


def make_functions(model: str):
    """Build the jittable (train_step, grad_step, evaluate) for ``model``."""
    layout_fn, forward = MODELS[model]
    layout = layout_fn()

    def loss_fn(flat, images, labels):
        logits = forward(layout.unpack(flat), images)
        return jnp.mean(_xent(logits, labels))

    def grad_step(flat, images, labels):
        loss, g = jax.value_and_grad(loss_fn)(flat, images, labels)
        return loss, g

    def train_step(flat, mom, images, labels, lr, mu, wd):
        loss, g = jax.value_and_grad(loss_fn)(flat, images, labels)
        new_flat, new_mom = fused_update.nesterov_update(flat, mom, g, lr, mu, wd)
        return new_flat, new_mom, loss

    def evaluate(flat, images, labels):
        logits = forward(layout.unpack(flat), images)
        losses = _xent(logits, labels)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
        return jnp.sum(losses), correct

    return layout, train_step, grad_step, evaluate


# Model-independent vector ops re-exported for aot.py.
pullback = fused_update.pullback
anchor_update = fused_update.anchor_update


def sgd_update(flat, mom, grad, lr, mu, wd):
    """Apply one fused Nesterov step with an externally supplied gradient
    (the sync-SGD / PowerSGD path: gradient was averaged by the coordinator)."""
    return fused_update.nesterov_update(flat, mom, grad, lr, mu, wd)


def adam_update(flat, m1, m2, grad, lr, t):
    """Fused Adam step (paper §6 extension: Overlap-Local-Adam).

    beta1/beta2/eps are the standard constants, baked at lowering; `t` is
    the 1-based step count for bias correction.
    """
    return fused_update.adam_update(flat, m1, m2, grad, lr, t,
                                    b1=0.9, b2=0.999, eps=1e-8, wd=0.0)
