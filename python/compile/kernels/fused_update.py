"""Layer-1 Pallas kernels: fused single-pass parameter-vector updates.

These are the paper's distinctive update operators, each fused into one pass
over the flat parameter vector so every parameter is read/written exactly
once per step (on a real TPU these ops are pure HBM-bandwidth; fusion is the
whole optimization — see DESIGN.md §Hardware-Adaptation):

* ``nesterov_update``  — the local optimizer step used by every algorithm
  (mu = 0 degenerates to plain SGD, so one artifact serves both variants):

      g' = g + wd * x
      v' = mu * v + g'
      x' = x - lr * (g' + mu * v')        (PyTorch-style Nesterov)

* ``pullback``         — Eq. (4) of the paper:  x' = x - alpha * (x - z)

* ``anchor_update``    — Eqs. (10)-(11):  v' = beta * v + (avg - z)
                                          z' = z + v'

Scalars (lr, mu, wd, alpha, beta) arrive as f32[1] inputs so a single AOT
artifact covers every hyper-parameter setting; they are broadcast to each
grid block via a constant (0,) index map.

Vectors are zero-padded to a block multiple by the wrappers; padding is a
fixed point of all three updates (0 maps to 0), so slicing back is exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elementwise block: 32768 f32 = 128 KiB per operand — a 5-operand kernel
# uses 640 KiB of VMEM (4 % of a TPU core's 16 MiB), and the large block
# amortizes per-grid-step overhead (measured 2.1x on the interpret path —
# EXPERIMENTS.md §Perf iteration 1).
BLOCK = 32768


def _pad1(x: jnp.ndarray, mult: int = BLOCK) -> jnp.ndarray:
    rem = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, rem),)) if rem else x


def _vec_spec():
    return pl.BlockSpec((BLOCK,), lambda i: (i,))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda i: (0,))


# --------------------------------------------------------------------------
# Nesterov / SGD fused optimizer step
# --------------------------------------------------------------------------


def _nesterov_kernel(x_ref, v_ref, g_ref, lr_ref, mu_ref, wd_ref, xo_ref, vo_ref):
    lr, mu, wd = lr_ref[0], mu_ref[0], wd_ref[0]
    g = g_ref[...] + wd * x_ref[...]
    v = mu * v_ref[...] + g
    xo_ref[...] = x_ref[...] - lr * (g + mu * v)
    vo_ref[...] = v


@jax.jit
def nesterov_update(x, v, g, lr, mu, wd):
    """Fused Nesterov-momentum step over flat f32 vectors.

    x, v, g: f32[N]; lr, mu, wd: f32[1]. Returns (x', v').
    """
    n = x.shape[0]
    xp, vp, gp = _pad1(x), _pad1(v), _pad1(g)
    np_ = xp.shape[0]
    xo, vo = pl.pallas_call(
        _nesterov_kernel,
        grid=(np_ // BLOCK,),
        in_specs=[_vec_spec(), _vec_spec(), _vec_spec(),
                  _scalar_spec(), _scalar_spec(), _scalar_spec()],
        out_specs=[_vec_spec(), _vec_spec()],
        out_shape=[jax.ShapeDtypeStruct((np_,), jnp.float32)] * 2,
        interpret=True,
    )(xp, vp, gp, lr, mu, wd)
    return xo[:n], vo[:n]


# --------------------------------------------------------------------------
# Pullback — Eq. (4)
# --------------------------------------------------------------------------


def _pullback_kernel(x_ref, z_ref, a_ref, o_ref):
    a = a_ref[0]
    o_ref[...] = x_ref[...] - a * (x_ref[...] - z_ref[...])


@jax.jit
def pullback(x, z, alpha):
    """Eq. (4): pull the local model toward the anchor. f32[N] -> f32[N]."""
    n = x.shape[0]
    xp, zp = _pad1(x), _pad1(z)
    np_ = xp.shape[0]
    out = pl.pallas_call(
        _pullback_kernel,
        grid=(np_ // BLOCK,),
        in_specs=[_vec_spec(), _vec_spec(), _scalar_spec()],
        out_specs=_vec_spec(),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(xp, zp, alpha)
    return out[:n]


# --------------------------------------------------------------------------
# Fused Adam step — the paper's §6 extension ("the key idea ... can be
# easily extended to other first-order algorithms, such as Adam").
# Bias correction uses the step count t (f32[1], 1-based).
# --------------------------------------------------------------------------


def _adam_kernel(x_ref, m_ref, v_ref, g_ref, lr_ref, t_ref,
                 xo_ref, mo_ref, vo_ref, *, b1, b2, eps, wd):
    lr, t = lr_ref[0], t_ref[0]
    g = g_ref[...] + wd * x_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1 ** t)
    vhat = v / (1.0 - b2 ** t)
    xo_ref[...] = x_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd"))
def adam_update(x, m, v, g, lr, t, *, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    """Fused Adam over flat f32 vectors: returns (x', m', v').

    x, m, v, g: f32[N]; lr, t: f32[1] (t is the 1-based step count for bias
    correction). Hyper-parameters are static (baked at lowering).
    """
    n = x.shape[0]
    xp, mp, vp, gp = _pad1(x), _pad1(m), _pad1(v), _pad1(g)
    np_ = xp.shape[0]
    xo, mo, vo = pl.pallas_call(
        functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=(np_ // BLOCK,),
        in_specs=[_vec_spec(), _vec_spec(), _vec_spec(), _vec_spec(),
                  _scalar_spec(), _scalar_spec()],
        out_specs=[_vec_spec(), _vec_spec(), _vec_spec()],
        out_shape=[jax.ShapeDtypeStruct((np_,), jnp.float32)] * 3,
        interpret=True,
    )(xp, mp, vp, gp, lr, t)
    return xo[:n], mo[:n], vo[:n]


# --------------------------------------------------------------------------
# Anchor momentum update — Eqs. (10)-(11)
# --------------------------------------------------------------------------


def _anchor_kernel(z_ref, v_ref, avg_ref, b_ref, zo_ref, vo_ref):
    beta = b_ref[0]
    v = beta * v_ref[...] + (avg_ref[...] - z_ref[...])
    zo_ref[...] = z_ref[...] + v
    vo_ref[...] = v


@jax.jit
def anchor_update(z, v, avg, beta):
    """Eqs. (10)-(11): momentum update of the anchor model.

    z, v, avg: f32[N]; beta: f32[1]. Returns (z', v'). beta = 0 reduces to
    the vanilla anchor assignment z' = avg (Eq. (5)).
    """
    n = z.shape[0]
    zp, vp, ap = _pad1(z), _pad1(v), _pad1(avg)
    np_ = zp.shape[0]
    zo, vo = pl.pallas_call(
        _anchor_kernel,
        grid=(np_ // BLOCK,),
        in_specs=[_vec_spec(), _vec_spec(), _vec_spec(), _scalar_spec()],
        out_specs=[_vec_spec(), _vec_spec()],
        out_shape=[jax.ShapeDtypeStruct((np_,), jnp.float32)] * 2,
        interpret=True,
    )(zp, vp, ap, beta)
    return zo[:n], vo[:n]
