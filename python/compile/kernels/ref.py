"""Pure-jnp oracles for every Layer-1 Pallas kernel.

pytest asserts ``assert_allclose(kernel(...), ref(...))`` over shape/seed
sweeps (see python/tests/). These definitions are the ground truth for the
algebra; the Pallas versions must match them bit-for-bit up to f32
accumulation-order noise.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_bias(x, w, b, *, fuse_relu: bool = False):
    out = x @ w + b
    return jnp.maximum(out, 0.0) if fuse_relu else out


def nesterov_update(x, v, g, lr, mu, wd):
    lr, mu, wd = lr[0], mu[0], wd[0]
    g = g + wd * x
    v_new = mu * v + g
    x_new = x - lr * (g + mu * v_new)
    return x_new, v_new


def pullback(x, z, alpha):
    return x - alpha[0] * (x - z)


def adam_update(x, m, v, g, lr, t, *, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    lr, t = lr[0], t[0]
    g = g + wd * x
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    mhat = m_new / (1.0 - b1 ** t)
    vhat = v_new / (1.0 - b2 ** t)
    x_new = x - lr * mhat / (jnp.sqrt(vhat) + eps)
    return x_new, m_new, v_new


def anchor_update(z, v, avg, beta):
    v_new = beta[0] * v + (avg - z)
    return z + v_new, v_new
