"""Layer-1 Pallas kernel: blocked matmul with optional fused bias + ReLU.

This is the FLOP hot-spot of every dense layer in the Layer-2 models. The
kernel is written TPU-style even though we lower it with ``interpret=True``
(the CPU PJRT plugin cannot execute Mosaic custom-calls):

* the grid is ``(M/bm, N/bn, K/bk)`` with ``k`` innermost so each output
  block stays resident while the contraction streams through;
* block sizes default to 128 — one MXU tile; 3 x 128 x 128 x 4 B = 192 KiB of
  VMEM per grid step (384 KiB double-buffered), far under the 16 MiB budget;
* bias-add and ReLU are fused into the *last* k-step so the output block is
  written exactly once (on a real TPU this saves a full HBM round-trip);
* the output block is its own accumulator — its block mapping is
  k-invariant, so it stays resident across the contraction (the classic
  "revisiting" accumulation pattern).

Inputs that do not tile evenly are zero-padded by the wrapper and the result
is sliced back; zero padding is exact for matmul + bias + ReLU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, target: int = 128) -> int:
    """Largest power-of-two block <= ``target`` that fits ``dim``."""
    if dim >= target:
        return target
    b = 8
    while b * 2 <= dim:
        b *= 2
    return b


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = [(0, (-dim) % mult) for dim, mult in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, fuse_relu: bool):
    """One (i, j, k) grid step: o += x_block @ w_block; epilogue on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...]
        if fuse_relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def _matmul_bias_impl(x, w, b, fuse_relu: bool):
    """``relu?(x @ w + b)`` via the blocked Pallas kernel (no autodiff)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    # Tried bk = 256 to halve the contraction grid depth: regressed the
    # lowered fwd+bwd by ~15 % on the XLA-CPU interpret path (larger fused
    # loop bodies thrash L1), so K stays at the 128 MXU tile (§Perf it. 2).
    bm, bn, bk = _block(m), _block(n), _block(k)
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    bp = _pad_to(b.reshape(1, n), (1, bn))
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk

    res = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, fuse_relu=fuse_relu),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return res[:m, :n]


# --------------------------------------------------------------------------
# Autodiff: custom VJP so the backward pass ALSO runs on the Pallas kernel.
#
# pallas_call has no JVP rule for grids using program_id, so we supply the
# closed-form matmul VJP ourselves — which is the production-quality choice
# anyway: dX = dY @ Wᵀ and dW = Xᵀ @ dY reuse the exact same blocked kernel,
# keeping the entire GEMM FLOP budget (fwd + bwd) on Layer 1.
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _matmul_bias_vjp(x, w, b, fuse_relu):
    return _matmul_bias_impl(x, w, b, fuse_relu)


def _mm_fwd(x, w, b, fuse_relu):
    out = _matmul_bias_impl(x, w, b, fuse_relu)
    # Residuals: inputs + (for ReLU) the activation mask via the output.
    return out, (x, w, out if fuse_relu else None)


def _mm_bwd(fuse_relu, res, dy):
    x, w, out = res
    if fuse_relu:
        dy = dy * (out > 0.0).astype(dy.dtype)
    zero_k = jnp.zeros((x.shape[1],), dtype=dy.dtype)
    zero_n = jnp.zeros((w.shape[1],), dtype=dy.dtype)
    dx = _matmul_bias_impl(dy, w.T, zero_k, False)
    dw = _matmul_bias_impl(x.T, dy, zero_n, False)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


_matmul_bias_vjp.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(jax.jit, static_argnames=("fuse_relu",))
def matmul_bias(x, w, b, *, fuse_relu: bool = False):
    """``relu?(x @ w + b)``, differentiable; fwd and bwd on the Pallas kernel.

    x: [M, K] f32, w: [K, N] f32, b: [N] f32 -> [M, N] f32.
    """
    return _matmul_bias_vjp(x, w, b, fuse_relu)
