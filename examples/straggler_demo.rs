//! Straggler mitigation demo (the paper's §2 claim, Fig. 3): when one node
//! runs 3x slower, fully-sync SGD drags everyone down to the straggler's
//! pace, while Overlap-Local-SGD's non-blocking anchor sync keeps the fast
//! workers busy.
//!
//! ```bash
//! make artifacts && cargo run --release --example straggler_demo
//! ```

use std::path::Path;

use anyhow::Result;

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::runtime::load_auto;
use olsgd::simnet::StragglerModel;

fn main() -> Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 8;
    cfg.tau = 4;
    cfg.epochs = 4.0;
    cfg.train_n = 1024;
    cfg.test_n = 300;

    let rt = load_auto(Path::new(&cfg.artifacts_dir), &cfg.model)?;
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

    println!("straggler demo: worker 0 runs 3x slower; m=8, tau=4\n");
    println!(
        "{:<12} {:<12} {:>14} {:>14} {:>12}",
        "algorithm", "straggler", "time/epoch(s)", "idle(s)", "slowdown"
    );

    for algo in [Algo::Sync, Algo::Local, Algo::OverlapM, Algo::Cocod] {
        let mut base_time = 0.0;
        for straggle in [false, true] {
            let mut c = cfg.clone();
            c.algo = algo;
            c.straggler = if straggle {
                StragglerModel::SlowNode { node: 0, factor: 3.0 }
            } else {
                StragglerModel::None
            };
            let log = run_experiment(&rt, &c, &train, &test)?;
            let tpe = log.time_per_epoch(c.epochs);
            if !straggle {
                base_time = tpe;
            }
            println!(
                "{:<12} {:<12} {:>14.2} {:>14.1} {:>11.2}x",
                algo.name(),
                if straggle { "3x slow" } else { "none" },
                tpe,
                log.total_idle_s,
                tpe / base_time
            );
        }
    }

    println!(
        "\nExpected shape: sync slows ~3x (everyone waits at each step's barrier);\n\
         overlap's slowdown is bounded by the slow node's own compute, with zero\n\
         idle time on the fast workers (the collective is non-blocking)."
    );
    Ok(())
}
