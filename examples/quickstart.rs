//! Quickstart: train the same model with fully-sync SGD, Local SGD, and
//! Overlap-Local-SGD, and print the paper's headline comparison — same
//! convergence, a fraction of the communication time.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;

use anyhow::Result;

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::runtime::load_auto;

fn main() -> Result<()> {
    // Small-but-real workload: 8 workers, synthetic-CIFAR, the scaled CNN.
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 8;
    cfg.epochs = 8.0;
    cfg.train_n = 1024;
    cfg.test_n = 500;
    cfg.tau = 2;

    let rt = load_auto(Path::new(&cfg.artifacts_dir), &cfg.model)?;
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

    println!("Overlap Local-SGD quickstart — m={} workers, tau={}, model={} ({} params)\n",
             cfg.workers, cfg.tau, cfg.model, rt.n);
    println!(
        "{:<12} {:>8} {:>12} {:>14} {:>12}",
        "algorithm", "acc%", "test loss", "time/epoch(s)", "comm ratio"
    );

    for algo in [Algo::Sync, Algo::Local, Algo::OverlapM] {
        let mut c = cfg.clone();
        c.algo = algo;
        let log = run_experiment(&rt, &c, &train, &test)?;
        println!(
            "{:<12} {:>8.2} {:>12.4} {:>14.2} {:>11.1}%",
            algo.name(),
            100.0 * log.final_acc(),
            log.final_loss(),
            log.time_per_epoch(c.epochs),
            100.0 * log.comm_ratio()
        );
    }

    println!(
        "\nExpected shape (paper Fig. 1/4): all three reach similar accuracy; \
         sync pays ~35% comm overhead, local ~{}x less, overlap ~none.",
        cfg.tau
    );
    Ok(())
}
