//! End-to-end driver (the mandated full-system validation): train the wide
//! CNN on synthetic-CIFAR with 8 workers for several hundred steps under
//! Overlap-Local-SGD with momentum, logging the loss curve, the virtual
//! cluster timeline, and the communication breakdown.
//!
//! This exercises every layer at once: Rust coordinator + simnet/clock +
//! non-blocking collective (L3), the AOT JAX train-step artifact (L2), and
//! the Pallas matmul / fused-Nesterov / pullback / anchor kernels inside it
//! (L1). The run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train [-- fast]
//! ```

use std::path::Path;

use anyhow::Result;

use olsgd::config::ExperimentConfig;
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::{write_json, write_text};
use olsgd::runtime::load_auto;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "fast");

    let mut cfg = ExperimentConfig::default();
    cfg.name = "e2e".into();
    cfg.model = "cnn_wide".into(); // 33k params, the largest artifact set
    cfg.workers = 8;
    cfg.tau = 2;
    cfg.epochs = if fast { 4.0 } else { 25.0 };
    cfg.train_n = if fast { 512 } else { 4096 };
    cfg.test_n = 500;
    cfg.eval_every = 1.0;

    let rt = load_auto(Path::new(&cfg.artifacts_dir), &cfg.model)?;
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

    let steps_per_epoch = cfg.train_n / cfg.workers / rt.train_batch;
    println!(
        "e2e: model={} ({} params), m={}, tau={}, {} epochs x {} steps/epoch = {} global steps",
        cfg.model,
        rt.n,
        cfg.workers,
        cfg.tau,
        cfg.epochs,
        steps_per_epoch,
        (cfg.epochs * steps_per_epoch as f64) as usize
    );

    let log = run_experiment(&rt, &cfg, &train, &test)?;

    println!("\nloss curve (train / test, per epoch):");
    println!(
        "{:>7} {:>8} {:>12} {:>12} {:>9} {:>12}",
        "epoch", "step", "train_loss", "test_loss", "acc%", "sim_time(s)"
    );
    for r in &log.records {
        println!(
            "{:>7.1} {:>8} {:>12.4} {:>12.4} {:>9.2} {:>12.1}",
            r.epoch, r.step, r.train_loss, r.test_loss, 100.0 * r.test_acc, r.sim_time
        );
    }

    println!("\ncluster timeline (virtual):");
    println!("  total            {:>10.1} s", log.total_sim_time);
    println!("  compute (sum)    {:>10.1} s", log.total_compute_s);
    println!("  comm blocked     {:>10.1} s", log.total_comm_blocked_s);
    println!("  straggler idle   {:>10.1} s", log.total_idle_s);
    println!("  comm/compute     {:>10.2} %", 100.0 * log.comm_ratio());
    println!("  bytes on wire    {:>10.1} MB", log.bytes_sent as f64 / 1e6);

    let out = Path::new("results/e2e");
    write_json(out, "e2e_train.json", &log.to_json())?;
    write_text(out, "e2e_train.csv", &log.to_csv())?;
    println!("\nwrote results/e2e/e2e_train.{{json,csv}}");

    // Sanity gate so CI catches regressions: the loss must actually fall.
    let first = log.records.first().map(|r| r.test_loss).unwrap_or(f64::NAN);
    let last = log.final_loss();
    anyhow::ensure!(
        last < first,
        "e2e training did not reduce test loss ({first:.4} -> {last:.4})"
    );
    println!("OK: test loss {first:.4} -> {last:.4}, acc {:.2}%", 100.0 * log.final_acc());
    Ok(())
}
