//! Non-IID showdown (the paper's §4 "Non-IID Data Partitions Setting" and
//! Table 2): every worker's shard is dominated by a single class (64 %,
//! mirroring the paper's 2000-of-3125 skew). CoCoD-SGD becomes unstable at
//! large τ while Overlap-Local-SGD's pullback keeps the replicas contracted
//! around the anchor.
//!
//! ```bash
//! make artifacts && cargo run --release --example noniid_showdown
//! ```

use std::path::Path;

use anyhow::Result;

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::runtime::load_auto;

fn main() -> Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 8;
    cfg.epochs = 10.0;
    cfg.train_n = 2048;
    cfg.test_n = 500;
    cfg.noniid = true;
    cfg.dominant_frac = 0.64; // the paper's 2000/3125
    cfg.reshuffle = false; // paper: "not shuffled during training"

    let rt = load_auto(Path::new(&cfg.artifacts_dir), &cfg.model)?;
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

    println!(
        "non-IID showdown: each of {} workers sees 64% one class; tau sweep\n",
        cfg.workers
    );
    println!(
        "{:<12} {:>6} {:>8} {:>12} {:>10}",
        "algorithm", "tau", "acc%", "test loss", "diverged?"
    );

    for algo in [Algo::Cocod, Algo::Eamsgd, Algo::OverlapM] {
        for tau in [2usize, 8] {
            let mut c = cfg.clone();
            c.algo = algo;
            c.tau = tau;
            let log = run_experiment(&rt, &c, &train, &test)?;
            let diverged = !log.final_loss().is_finite() || log.final_loss() > 5.0;
            println!(
                "{:<12} {:>6} {:>8.2} {:>12.4} {:>10}",
                algo.name(),
                tau,
                100.0 * log.final_acc(),
                log.final_loss(),
                if diverged { "DIVERGED" } else { "no" }
            );
        }
    }

    println!(
        "\nExpected shape (paper Table 2): overlap-m stays stable as tau grows;\n\
         cocod degrades/diverges first, eamsgd degrades fastest in accuracy."
    );
    Ok(())
}
